//! The CHEETAH server: holds the model, performs the perm-free obscure
//! linear computation (paper §3.1–3.3), and finishes the nonlinear step by
//! decrypting its share of the recovered activation.
//!
//! Per query and per fused step `linear [+ReLU] [+pool]`:
//!
//! 1. receive `[T(share_C)]_C` — client-encrypted expanded client share,
//! 2. compute `T(share_S)` locally (shares are mod-p; `T` is linear),
//! 3. per output channel: `MultPlain` by the blinded kernel `k'∘v`, then
//!    `AddPlain` of `k'v∘T(share_S) + b` — **zero permutations**,
//! 4. send the obscured products back; the client block-sums in plaintext,
//! 5. receive the recovery ciphertexts `[ReLU(Con+δ) − s₁]_S`, decrypt →
//!    the server's additive share of the next activation,
//! 6. shares are sum-pooled locally when the network pools (the mean
//!    divisor was absorbed into this step's weights at preparation time).
//!
//! Timing is split into `online` (query-dependent work the paper measures)
//! and `offline` (weight/blinding material preparation, amortizable).

use super::blinding::{sample_block_noise, Blind};
use super::spec::{LinearSpec, ProtocolSpec, SpecError, StepSpec};
use crate::fixed::ScalePlan;

use crate::nn::Network;
use crate::par;
use crate::phe::params::NUM_Q_PRIMES;
use crate::phe::scratch::Arena;
use crate::phe::{Ciphertext, Context, Encryptor, Evaluator, Form, OpCounts, PlainOperand};
use crate::util::rng::ChaCha20Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Per-tap additive-noise magnitude bound (see `fixed` docs: products ≤
/// ~2^21, noise ≤ 2^17 keeps every slot within ±(p−1)/2).
pub const NOISE_BOUND: i64 = 1 << 17;

/// Default per-step operand-cache budget in bytes: the `CHEETAH_OPERAND_CACHE_MB`
/// env var, else 256 MB. Steps whose prepared-operand footprint fits the
/// budget cache everything at [`CheetahServer::refresh_blinding`] time and
/// score with **zero** per-query operand construction; over-budget steps
/// (paper-scale VGG conv grids) fall back to tiled per-query construction
/// whose transient memory is bounded by the same budget per tile.
fn default_operand_cache_bytes() -> usize {
    static BUDGET: OnceLock<usize> = OnceLock::new();
    *BUDGET.get_or_init(|| {
        let mb = std::env::var("CHEETAH_OPERAND_CACHE_MB")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(256);
        mb.saturating_mul(1024 * 1024)
    })
}

/// Online/offline compute timer snapshot ([`CheetahServer::timers`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct Timers {
    /// Query-dependent work (the paper's "online time").
    pub online: Duration,
    /// Query-independent preparation (amortizable offline work).
    pub offline: Duration,
}

/// Interior-mutable nanosecond accumulators behind the [`Timers`]
/// snapshots, so the `&self` scoring core (shared by concurrent batch
/// queries) can time itself. Concurrent queries fold into one total —
/// per-query attribution in batch mode is the batch driver's job.
#[derive(Default)]
struct TimerCell {
    online_ns: AtomicU64,
    offline_ns: AtomicU64,
}

impl TimerCell {
    fn add_online(&self, d: Duration) {
        self.online_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn add_offline(&self, d: Duration) {
        self.offline_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn snapshot(&self) -> Timers {
        Timers {
            online: Duration::from_nanos(self.online_ns.load(Ordering::Relaxed)),
            offline: Duration::from_nanos(self.offline_ns.load(Ordering::Relaxed)),
        }
    }

    fn take(&self) -> Timers {
        Timers {
            online: Duration::from_nanos(self.online_ns.swap(0, Ordering::Relaxed)),
            offline: Duration::from_nanos(self.offline_ns.swap(0, Ordering::Relaxed)),
        }
    }
}

/// Offline material for one step: the blinding draws plus the prepared
/// operand cache. The cached components are what make the online phase of
/// [`CheetahServer::step_linear_with`] construction-free — they are built
/// once per [`CheetahServer::refresh_blinding`] and reused by every query
/// (GAZELLE/GALA hoist exactly this plaintext-operand preparation offline).
/// Each component is cached only while the step fits the per-step budget
/// ([`default_operand_cache_bytes`]); `None` means the scoring path builds
/// it per query, tile by tile, with offline attribution.
struct PreparedStep {
    /// Quantized kernel taps per output channel (weights pre-divided by the
    /// inherited pool divisor): `kq[channel][tap]`.
    kq: Vec<Vec<i64>>,
    /// Blinding factor per output index (channel-major).
    #[allow(dead_code)]
    blinds: Vec<Blind>,
    /// `v₁` as fixed-point int per output index.
    v_int: Vec<i64>,
    /// Noise targets `v₁·δ` per output index, at the product scale.
    targets: Vec<i64>,
    /// ChaCha20 key for the per-tap noise streams `b`: channel `ch` draws
    /// from stream id `ch` of this key, so channel streams are disjoint by
    /// the cipher's nonce separation (no seed-XOR collisions across
    /// channels or steps — see the `protocol::cheetah` module docs).
    noise_key: [u8; 32],
    /// Server-encrypted polar indicators, output-indexed packing
    /// (transmitted to the client in the offline phase).
    id1: Vec<Ciphertext>,
    id2: Vec<Ciphertext>,
    /// NTT-form `MultPlain` operands `k'∘v`, one per (channel × input-ct)
    /// slot, channel-major.
    kv_ops: Option<Vec<PlainOperand>>,
    /// First step only: `AddPlain` operands of `b` alone (the first layer's
    /// whole additive operand — the server share is zero on a fresh query).
    b_ops: Option<Vec<PlainOperand>>,
    /// Hidden steps: per-channel noise-stream residues mod p, indexed
    /// `[channel][stream position]` — the query-independent half of the
    /// online `k'v∘T(share_S) + b` operand.
    noise_res: Option<Vec<Vec<u64>>>,
}

impl PreparedStep {
    /// The empty prepared state of a local (zero-ciphertext) step — an
    /// [`super::spec::LinearSpec::AvgPool`] step exchanges nothing and
    /// needs no blinding, indicators, or operands. Skipping the blinding
    /// draws entirely (rather than sampling and discarding) also keeps the
    /// RNG sequence of the *other* steps identical to what a pool-free
    /// network with the same seed would draw.
    fn empty() -> Self {
        Self {
            kq: Vec::new(),
            blinds: Vec::new(),
            v_int: Vec::new(),
            targets: Vec::new(),
            noise_key: [0u8; 32],
            id1: Vec::new(),
            id2: Vec::new(),
            kv_ops: None,
            b_ops: None,
            noise_res: None,
        }
    }
}

/// The server side of the CHEETAH protocol. Owns a shared `Arc<Context>`,
/// so prepared engines move freely between serving threads (blinding pool,
/// session workers) with no lifetime plumbing.
///
/// Scoring is **stateless** (`&self`): the per-query state — the server's
/// additive share of the activation chain — lives outside the engine and is
/// threaded through [`CheetahServer::step_linear_with`] /
/// [`CheetahServer::finish_nonlinear_with`]. One prepared engine therefore
/// serves any number of concurrent queries (the batch driver in
/// [`super::runner::CheetahRunner::infer_batch`] and the serve sessions
/// both rely on this). The `&mut self` wrappers ([`CheetahServer::begin_query`],
/// [`CheetahServer::step_linear`], …) keep one internal share for the
/// classic single-query call sequence.
pub struct CheetahServer {
    /// Shared PHE context (parameters, encoder, NTT tables).
    pub ctx: Arc<Context>,
    /// Homomorphic evaluator (op counters are atomic — `Sync`).
    pub ev: Evaluator,
    /// The server's encryptor/decryptor (holds the server secret key).
    pub enc: Encryptor,
    /// Fixed-point scale plan shared with the client.
    pub plan: ScalePlan,
    /// Compiled protocol spec both parties agree on.
    pub spec: ProtocolSpec,
    /// Obscuring-noise bound ε (0.0 = exact inference).
    pub epsilon: f64,
    net: Network,
    steps: Vec<PreparedStep>,
    /// Server's additive share (mod p) of the current activation — the
    /// single-query convenience state behind the `&mut self` wrappers.
    share: Vec<u64>,
    rng: ChaCha20Rng,
    timers: TimerCell,
    /// Per-step byte budget for the prepared-operand cache (and the bound
    /// on per-tile transient memory when a step overflows it).
    cache_budget: usize,
    /// Reusable scratch buffers for the online phase's query-dependent
    /// `AddPlain` operands (see [`crate::phe::scratch`]).
    scratch: Arena,
}

impl CheetahServer {
    /// Prepare the model: quantize weights, sample per-query-independent
    /// blinding, and encrypt the indicator vectors. (The paper prepares
    /// v/b/ID offline per query; we re-prepare per `refresh_blinding` call —
    /// `new` counts as the first offline phase.) A network the protocol
    /// cannot express is a typed [`SpecError`], not a panic.
    pub fn new(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
    ) -> Result<Self, SpecError> {
        let spec = ProtocolSpec::compile(&net)?;
        Ok(Self::with_spec(ctx, net, spec, plan, epsilon, seed))
    }

    /// Like [`CheetahServer::new`] with an already-validated spec —
    /// infallible, so serving-path builders (the blinding pool) that
    /// validated the network once at configuration time never risk a
    /// worker-thread death on a malformed architecture.
    pub fn with_spec(
        ctx: Arc<Context>,
        net: Network,
        spec: ProtocolSpec,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
    ) -> Self {
        Self::with_spec_and_cache(ctx, net, spec, plan, epsilon, seed, default_operand_cache_bytes())
    }

    /// Like [`CheetahServer::new`] with an explicit per-step operand-cache
    /// budget in bytes (`0` disables caching entirely — every query rebuilds
    /// its operands tile by tile, the pre-cache behavior). The budget never
    /// affects the blinding draws, so two servers with the same seed and
    /// different budgets produce bit-identical ciphertexts and logits (the
    /// cached-vs-rebuild equivalence test relies on this).
    pub fn with_cache_budget(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
        cache_bytes: usize,
    ) -> Result<Self, SpecError> {
        let spec = ProtocolSpec::compile(&net)?;
        Ok(Self::with_spec_and_cache(ctx, net, spec, plan, epsilon, seed, cache_bytes))
    }

    fn with_spec_and_cache(
        ctx: Arc<Context>,
        net: Network,
        spec: ProtocolSpec,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
        cache_bytes: usize,
    ) -> Self {
        let mut rng = ChaCha20Rng::from_u64_seed(seed);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        plan.check_fits(ctx.params.p);
        let mut server = Self {
            ev: Evaluator::new(ctx.clone()),
            enc,
            plan,
            spec,
            epsilon,
            net,
            steps: Vec::new(),
            share: Vec::new(),
            ctx,
            rng,
            timers: TimerCell::default(),
            cache_budget: cache_bytes,
            scratch: Arena::new(),
        };
        server.refresh_blinding();
        server
    }

    /// The scratch arena backing the online phase (hit-rate metering and
    /// test instrumentation; see [`crate::phe::scratch`]).
    pub fn scratch(&self) -> &Arena {
        &self.scratch
    }

    /// (Re-)sample all per-query blinding material, re-encrypt the
    /// indicator ciphertexts, and rebuild the prepared-operand cache — the
    /// offline phase. After this returns, every budget-fitting step scores
    /// with zero per-query operand construction (the blinding-pool
    /// background builds in `serve::precompute` therefore bank fully
    /// prepared operands, not just blinding draws).
    pub fn refresh_blinding(&mut self) {
        let _span = crate::obs::span("cheetah.offline.refresh");
        let t0 = Instant::now();
        let prod_scale = self.plan.product();
        let mut steps = Vec::with_capacity(self.spec.steps.len());
        for (si, step) in self.spec.steps.iter().enumerate() {
            if step.is_local() {
                // Local steps (standalone AvgPool) move no ciphertexts:
                // both parties sum-pool their own shares, so there is
                // nothing to prepare.
                steps.push(PreparedStep::empty());
                continue;
            }
            let n_out = step.linear.num_outputs();
            let last = si == self.spec.last_idx();
            let kq = self.quantize_weights(step);
            let mut blinds = Vec::with_capacity(n_out);
            let mut v_int = Vec::with_capacity(n_out);
            let mut targets = Vec::with_capacity(n_out);
            // The last layer uses one shared positive blind (the paper's
            // ideal functionality reveals the last linear result under a
            // single v) — we use the identity so logits keep their scale.
            for _ in 0..n_out {
                let b = if last { Blind::identity() } else { Blind::sample(&mut self.rng) };
                let delta = if self.epsilon > 0.0 {
                    let u = self.rng.gen_range(1 << 24) as f64 / (1u64 << 23) as f64 - 1.0;
                    prod_scale.quantize(u * self.epsilon)
                } else {
                    0
                };
                v_int.push(b.v1_int(&self.plan));
                // target = v1·δ at product scale: v1 is a power of two ⇒
                // shift δ (sampled at product scale) by j and sign.
                let shifted = match b.j {
                    1 => delta * 2,
                    0 => delta,
                    _ => delta / 2,
                };
                targets.push(shifted * b.s as i64);
                blinds.push(b);
            }
            // Indicator ciphertexts (skipped for the last layer).
            let (id1, id2) = if last {
                (Vec::new(), Vec::new())
            } else {
                let n = self.ctx.params.n;
                let mut id1_vals = vec![0i64; n_out];
                let mut id2_vals = vec![0i64; n_out];
                for (i, b) in blinds.iter().enumerate() {
                    let (a, c) = b.indicator(&self.plan);
                    id1_vals[i] = a;
                    id2_vals[i] = c;
                }
                let n_cts = step.linear.num_recovery_cts(n);
                let mut id1 = Vec::with_capacity(n_cts);
                let mut id2 = Vec::with_capacity(n_cts);
                for c in 0..n_cts {
                    let lo = c * n;
                    let hi = ((c + 1) * n).min(n_out);
                    id1.push(self.enc.encrypt_slots(&id1_vals[lo..hi], &mut self.rng));
                    id2.push(self.enc.encrypt_slots(&id2_vals[lo..hi], &mut self.rng));
                }
                (id1, id2)
            };
            let mut prep = PreparedStep {
                kq,
                blinds,
                v_int,
                targets,
                noise_key: ChaCha20Rng::key_from_u64(self.rng.next_u64()),
                id1,
                id2,
                kv_ops: None,
                b_ops: None,
                noise_res: None,
            };
            self.build_operand_cache(si, step, &mut prep);
            steps.push(prep);
        }
        self.steps = steps;
        // Warm the scratch arena once (only on the first refresh), sized
        // for the wider of the current `par` setting and the host's
        // parallelism — so a first query at any in-hardware thread count
        // allocates nothing in the online phase. An explicit
        // `with_threads` scope wider than the host may still fresh-allocate
        // a few buffers on its first query (they bank for reuse after);
        // tests that assert strict zero-alloc reserve explicitly.
        if self.scratch.stats().reserved == 0 {
            let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            self.scratch.reserve(&self.ctx.params, par::threads().max(host) + 2);
        }
        self.timers.add_offline(t0.elapsed());
    }

    /// Build the prepared-operand cache for one step, component by
    /// component in payoff order (`kv_ops`, then the additive material)
    /// while the step stays within the per-step budget. Uses no RNG state —
    /// the blinding draws above are identical whatever the budget, which is
    /// what keeps cached and rebuild-per-query deployments bit-identical.
    /// Transient memory is bounded per worker (one channel's noise stream /
    /// one slot's tap values), never the whole (channel × ct) grid.
    fn build_operand_cache(&self, si: usize, step: &StepSpec, prep: &mut PreparedStep) {
        let n = self.ctx.params.n;
        let len = step.linear.stream_len();
        let n_cts = step.linear.num_in_cts(n);
        let channels = step.linear.num_channels();
        let grid = channels * n_cts;
        let poly_mem = NUM_Q_PRIMES * n * 8;
        let mut remaining = self.cache_budget;

        let kv_cost = grid * poly_mem;
        if kv_cost <= remaining {
            remaining -= kv_cost;
            let prep_ref: &PreparedStep = prep;
            let ops = par::map_indexed(grid, |k| {
                self.build_kv_op(prep_ref, step, k / n_cts, k % n_cts)
            });
            prep.kv_ops = Some(ops);
        } else {
            return; // additive material is cheaper but useless without kv_ops
        }

        if si == 0 {
            // First layer: the whole additive operand is query-independent.
            if grid * poly_mem <= remaining {
                let prep_ref: &PreparedStep = prep;
                let per_channel: Vec<Vec<PlainOperand>> = par::map_indexed(channels, |ch| {
                    let noise = self.channel_noise_residues(prep_ref, step, ch);
                    (0..n_cts)
                        .map(|c| {
                            let lo = c * n;
                            let hi = ((c + 1) * n).min(len);
                            self.ctx.add_operand_unsigned(&noise[lo..hi])
                        })
                        .collect()
                });
                prep.b_ops = Some(per_channel.into_iter().flatten().collect());
            }
        } else if channels * len * 8 <= remaining {
            // Hidden layers: the additive operand is query-dependent, but
            // its noise half is not — cache the residue streams.
            let prep_ref: &PreparedStep = prep;
            let noise = par::map_indexed(channels, |ch| {
                self.channel_noise_residues(prep_ref, step, ch)
            });
            prep.noise_res = Some(noise);
        }
    }

    /// One channel's full noise stream `b` as residues mod p, drawn from
    /// stream `ch` of the step's noise key (domain-separated per channel —
    /// thread-count-invariant by construction).
    fn channel_noise_residues(&self, prep: &PreparedStep, step: &StepSpec, ch: usize) -> Vec<u64> {
        let p = self.ctx.params.p;
        let blocks = step.linear.blocks_per_channel();
        let block = step.linear.block_len();
        let mut nrng = ChaCha20Rng::new(&prep.noise_key, ch as u64);
        let mut out = Vec::with_capacity(blocks * block);
        for blk in 0..blocks {
            for b in sample_block_noise(block, prep.targets[ch * blocks + blk], NOISE_BOUND, &mut nrng)
            {
                out.push(if b < 0 { p - ((-b) as u64 % p) } else { b as u64 % p });
            }
        }
        out
    }

    /// The `MultPlain` operand `k'∘v` for one (channel, input-ct) slot.
    fn build_kv_op(&self, prep: &PreparedStep, step: &StepSpec, ch: usize, c: usize) -> PlainOperand {
        let n = self.ctx.params.n;
        let len = step.linear.stream_len();
        let blocks = step.linear.blocks_per_channel();
        let block = step.linear.block_len();
        let lo = c * n;
        let hi = ((c + 1) * n).min(len);
        let mut kv = vec![0i64; hi - lo];
        for (slot, g) in (lo..hi).enumerate() {
            kv[slot] = kv_int(prep, &step.linear, blocks, block, ch, g);
        }
        self.ctx.mult_operand(&kv)
    }

    /// Quantized kernel taps per channel, with the inherited pool divisor
    /// folded in (`mean = sum / div` absorbed into the next linear layer).
    /// Pure per-channel work, fanned out across the pool (this runs inside
    /// every blinding-pool background build).
    fn quantize_weights(&self, step: &StepSpec) -> Vec<Vec<i64>> {
        let layer = &self.net.layers[step.layer_idx];
        let div = step.weight_div;
        let plan = &self.plan;
        match &step.linear {
            LinearSpec::Conv(p) => {
                let (c_i, _, _) = p.in_shape;
                let r = p.kernel;
                par::map_indexed(p.out_shape.0, |o| {
                    (0..p.block)
                        .map(|t| {
                            let i = t / (r * r);
                            let rem = t % (r * r);
                            plan.quant_k(layer.conv_w(c_i, r, o, i, rem / r, rem % r) / div)
                        })
                        .collect()
                })
            }
            LinearSpec::Fc(p) => {
                // FC: one "channel"; blocks are output neurons, so kq is
                // indexed per block at multiplier-build time. Store rows.
                par::map_indexed(p.n_o, |o| {
                    (0..p.n_i).map(|j| plan.quant_k(layer.fc_w(p.n_i, o, j) / div)).collect()
                })
            }
            // Local steps carry no weights (their mean divisor is folded
            // into the *next* linear step at compile time).
            LinearSpec::AvgPool { .. } => Vec::new(),
        }
    }

    /// The indicator ciphertexts for step `si` (offline transmission).
    pub fn indicator_cts(&self, si: usize) -> (&[Ciphertext], &[Ciphertext]) {
        (&self.steps[si].id1, &self.steps[si].id2)
    }

    /// A zeroed server-side share for a fresh query (at step 0 the client
    /// holds the whole input) — the starting per-query state for the
    /// stateless scoring path ([`CheetahServer::step_linear_with`]).
    pub fn fresh_share(&self) -> Vec<u64> {
        let (c, h, w) = self.spec.input_shape;
        vec![0u64; c * h * w]
    }

    /// Begin a query on the internal single-query state: the client holds
    /// the whole input, so the server's initial share is zero.
    pub fn begin_query(&mut self) {
        self.share = self.fresh_share();
    }

    /// Direct share injection (tests / mid-network entry).
    pub fn set_share(&mut self, share: Vec<u64>) {
        self.share = share;
    }

    /// The internal single-query share (after the wrappers ran).
    pub fn share(&self) -> &[u64] {
        &self.share
    }

    /// Single-query wrapper over [`CheetahServer::step_linear_with`] using
    /// the internal share set by [`CheetahServer::begin_query`] /
    /// [`CheetahServer::finish_nonlinear`].
    pub fn step_linear(&mut self, si: usize, in_cts: &[Ciphertext]) -> Vec<Ciphertext> {
        self.step_linear_with(si, in_cts, &self.share)
    }

    /// The obscure linear computation for step `si`. Input: the client's
    /// encrypted expanded share and the server's additive share of the
    /// current activation (`share`; zeros for step 0). Output:
    /// channel-major obscured-product ciphertexts (`channels × num_in_cts`).
    ///
    /// **Offline/online split.** With a warm operand cache (every step that
    /// fits the per-step budget — see [`CheetahServer::with_cache_budget`])
    /// the online phase is exactly the paper's claim: NTT ingest, then one
    /// `MultPlain` (cached `k'∘v` operand, built single-pass into the
    /// output ciphertext) plus one `AddPlain` per output ciphertext, each
    /// landing in its pre-sized channel-major slot. Hidden layers
    /// additionally build their query-dependent additive operand
    /// `k'v∘T(share_S) + b` — inherently online work — but into reusable
    /// arena scratch, so the online phase **allocates no operand
    /// polynomials** (asserted by `online_phase_builds_no_operand_polys`).
    /// Only over-budget steps construct operands here, tile by tile
    /// (offline-attributed, transient memory bounded by the budget per
    /// tile — never the whole (channel × ct) grid).
    ///
    /// The (channel × input-ct) grid is the paper's embarrassingly parallel
    /// unit and fans out across the [`crate::par`] pool (via
    /// [`crate::par::map_indexed_grained`], so tiny FC-tail grids skip the
    /// fork-join handshake). Results land in channel-ordered slots and each
    /// channel's
    /// noise stream comes from its own ChaCha20 stream id, so the output is
    /// bit-identical at every thread count.
    ///
    /// `&self`: all mutable state is the caller-owned `share`, so any
    /// number of queries may score concurrently against one prepared
    /// engine (they share the blinding material — exactly like repeated
    /// queries on one deployment).
    pub fn step_linear_with(
        &self,
        si: usize,
        in_cts: &[Ciphertext],
        share: &[u64],
    ) -> Vec<Ciphertext> {
        let _span = crate::obs::span("cheetah.online.step_linear");
        let step = &self.spec.steps[si];
        if step.is_local() {
            // Local steps exchange no ciphertexts; the share transform is
            // [`CheetahServer::local_share`]. Returning an empty product
            // list keeps lockstep drivers uniform.
            assert!(in_cts.is_empty(), "local steps take no input ciphertexts");
            return Vec::new();
        }
        let prep = &self.steps[si];
        let params = &self.ctx.params;
        let n = params.n;
        let p = params.p;
        let len = step.linear.stream_len();
        let n_cts = step.linear.num_in_cts(n);
        assert_eq!(in_cts.len(), n_cts, "wrong input ciphertext count");
        let channels = step.linear.num_channels();
        let blocks = step.linear.blocks_per_channel();
        let block = step.linear.block_len();

        // Online: convert incoming ciphertexts to NTT form once (parallel
        // batch), and expand the server's share T(share_S). A fresh query's
        // first layer (zero server share, cached `b` operands) skips the
        // expansion; a zero share *without* cached operands still runs the
        // generic path — T(0) = 0, so the additive operand degenerates to
        // `b` alone and the result is identical.
        let t_on = Instant::now();
        let mut in_ntt: Vec<Ciphertext> = in_cts.to_vec();
        self.ev.to_ntt_batch(&mut in_ntt);
        let share_zero = share.iter().all(|&s| s == 0);
        let first_layer = share_zero && prep.b_ops.is_some();
        let ts: Vec<u64> =
            if first_layer { Vec::new() } else { step.linear.expand_u64(share) };
        self.timers.add_online(t_on.elapsed());

        // Tile sizing: fully cached steps stream the whole grid as one
        // "tile" with no offline block at all; uncached steps bound their
        // per-tile operand memory by the cache budget.
        let need_kv = prep.kv_ops.is_none();
        let need_noise = !first_layer && prep.noise_res.is_none();
        // Cache observability: did this step score from the prepared-operand
        // cache, or stream tiles (rebuilding operands per query)?
        if need_kv || need_noise {
            crate::obs::inc("cheetah.steps.streamed");
        } else {
            crate::obs::inc("cheetah.steps.cached");
        }
        let tile_ch = if need_kv || need_noise {
            let poly_mem = NUM_Q_PRIMES * n * 8;
            let per_ch = n_cts * poly_mem + len * 8;
            (self.cache_budget / per_ch.max(1)).clamp(1, channels)
        } else {
            channels
        };

        let ev = &self.ev;
        let ctx = &self.ctx;
        let linear = &step.linear;
        let mut out: Vec<Ciphertext> = Vec::with_capacity(channels * n_cts);
        let mut tlo = 0;
        while tlo < channels {
            let thi = (tlo + tile_ch).min(channels);
            // Offline-attributed: per-tile operand construction for steps
            // whose prepared grid overflowed the cache budget. Transient:
            // one tile's operands, freed before the next tile.
            let (tile_kv, tile_noise) = if need_kv || need_noise {
                let t_off = Instant::now();
                let tile_noise: Option<Vec<Vec<u64>>> = need_noise.then(|| {
                    par::map_indexed_grained(thi - tlo, 2, |i| {
                        self.channel_noise_residues(prep, step, tlo + i)
                    })
                });
                let tile_kv: Option<Vec<PlainOperand>> = need_kv.then(|| {
                    par::map_indexed_grained((thi - tlo) * n_cts, 2, |k| {
                        self.build_kv_op(prep, step, tlo + k / n_cts, k % n_cts)
                    })
                });
                self.timers.add_offline(t_off.elapsed());
                (tile_kv, tile_noise)
            } else {
                (None, None)
            };

            // Online: 1 MultPlain + 1 AddPlain per ciphertext over the tile
            // grid, each result written into its preallocated channel-major
            // slot; hidden-layer additive operands build in arena scratch.
            let t_on = Instant::now();
            let tile_out: Vec<Ciphertext> =
                par::map_indexed_grained((thi - tlo) * n_cts, 2, |k| {
                    let (ch_rel, c) = (k / n_cts, k % n_cts);
                    let ch = tlo + ch_rel;
                    let gk = ch * n_cts + c;
                    let kv_op: &PlainOperand = match &prep.kv_ops {
                        Some(ops) => &ops[gk],
                        None => &tile_kv.as_ref().expect("tile kv ops built")[k],
                    };
                    // Single-pass product straight into this slot's output
                    // ciphertext (no clone, no zero-fill).
                    let mut prod = ev.mult_plain(&in_ntt[c], kv_op);
                    if first_layer {
                        let b_ops = prep.b_ops.as_ref().expect("first-layer ops cached");
                        ev.add_plain(&mut prod, &b_ops[gk]);
                    } else {
                        let noise: &[u64] = match &prep.noise_res {
                            Some(nr) => &nr[ch],
                            None => &tile_noise.as_ref().expect("tile noise built")[ch_rel],
                        };
                        let lo = c * n;
                        let hi = ((c + 1) * n).min(len);
                        let mut vals = self.scratch.slots(hi - lo);
                        for (slot, g) in (lo..hi).enumerate() {
                            let kv = kv_int(prep, linear, blocks, block, ch, g);
                            let kv_res =
                                if kv < 0 { p - ((-kv) as u64 % p) } else { kv as u64 % p };
                            vals[slot] =
                                (crate::util::math::mul_mod(kv_res, ts[g], p) + noise[g]) % p;
                        }
                        let mut pt = self.scratch.plain(n);
                        ctx.encoder.encode_unsigned_into(&vals, &mut pt);
                        let mut poly = self.scratch.poly(params, Form::Coeff);
                        ctx.scale_plain_into(&pt, &mut poly);
                        ctx.to_ntt(&mut poly);
                        ev.add_plain_raw(&mut prod, &poly);
                    }
                    prod
                });
            out.extend(tile_out);
            self.timers.add_online(t_on.elapsed());
            tlo = thi;
        }
        out
    }

    /// Single-query wrapper over [`CheetahServer::advance_share`]: stores
    /// the next share in the internal single-query state (and applies the
    /// residual skip-add when the step carries one).
    pub fn finish_nonlinear(&mut self, si: usize, rec_cts: &[Ciphertext]) {
        let next = self.advance_share(si, rec_cts, &self.share);
        self.share = next;
    }

    /// Single-query wrapper over [`CheetahServer::local_share`] for a local
    /// (AvgPool) step: transforms the internal share in place.
    pub fn finish_local(&mut self, si: usize) {
        let next = self.local_share(si, &self.share);
        self.share = next;
    }

    /// [`CheetahServer::finish_nonlinear_with`] plus the residual skip-add:
    /// when step `si` carries `residual_add`, the server adds its own saved
    /// share of the step's *input* activation (`prev`, mod p) to the
    /// decrypted output share — the client does the same with its shares,
    /// so the reconstruction gains exactly `ReLU(linear(x)) + x`
    /// (share-level adds commute with reconstruction; no extra ciphertexts
    /// or rounds). `prev` must be the share that fed this step's
    /// [`CheetahServer::step_linear_with`].
    pub fn advance_share(&self, si: usize, rec_cts: &[Ciphertext], prev: &[u64]) -> Vec<u64> {
        let mut share = self.finish_nonlinear_with(si, rec_cts);
        let step = &self.spec.steps[si];
        if step.residual_add {
            let p = self.ctx.params.p;
            assert_eq!(share.len(), prev.len(), "residual shapes must match");
            for (dst, &old) in share.iter_mut().zip(prev) {
                *dst = (*dst + old) % p;
            }
        }
        share
    }

    /// The share transform of a local (zero-ciphertext) step: both parties
    /// sum-pool their own additive shares mod p — linearity of the sum-pool
    /// makes the reconstruction the pooled activation, and the mean divisor
    /// was folded into the next linear step's weights at compile time.
    pub fn local_share(&self, si: usize, share: &[u64]) -> Vec<u64> {
        let _span = crate::obs::span("cheetah.online.local_share");
        let step = &self.spec.steps[si];
        let t0 = Instant::now();
        let out = match &step.linear {
            LinearSpec::AvgPool { shape, size } => {
                pool_shares(share, *shape, *size, self.ctx.params.p)
            }
            _ => panic!("local_share called on a non-local step"),
        };
        self.timers.add_online(t0.elapsed());
        out
    }

    /// Finish the nonlinear step: decrypt the recovery ciphertexts into the
    /// server's share of the (ReLU'd, requantized) activation, applying the
    /// share-domain sum-pool when the network pools here. Returns the
    /// next-layer share (`&self` — see [`CheetahServer::step_linear_with`]
    /// on concurrent queries).
    pub fn finish_nonlinear_with(&self, si: usize, rec_cts: &[Ciphertext]) -> Vec<u64> {
        let _span = crate::obs::span("cheetah.online.finish_nonlinear");
        let step = &self.spec.steps[si];
        let n = self.ctx.params.n;
        let n_out = step.linear.num_outputs();
        assert_eq!(rec_cts.len(), step.linear.num_recovery_cts(n));
        let t0 = Instant::now();
        // Each recovery ciphertext decrypts independently — parallel batch
        // (grained: single-ciphertext FC tails skip dispatch), concatenated
        // in ciphertext order.
        let enc = &self.enc;
        let ctx = &self.ctx;
        let parts: Vec<Vec<u64>> = par::map_indexed_grained(rec_cts.len(), 2, |c| {
            let vals = ctx.encoder.decode_unsigned(&enc.decrypt(&rec_cts[c]));
            let hi = ((c + 1) * n).min(n_out) - c * n;
            vals[..hi].to_vec()
        });
        let mut share = Vec::with_capacity(n_out);
        for part in parts {
            share.extend(part);
        }
        if let Some(size) = step.pool_after {
            share = pool_shares(&share, step.out_shape, size, self.ctx.params.p);
        }
        self.timers.add_online(t0.elapsed());
        share
    }

    /// Total bytes currently held by the prepared-operand cache across all
    /// steps (operand polys + noise residues) — the deployment memory spent
    /// to make the online phase construction-free. `0` means every step
    /// overflowed the budget (or caching was disabled) and queries rebuild
    /// operands tile by tile.
    pub fn cached_operand_bytes(&self) -> usize {
        let poly_mem = NUM_Q_PRIMES * self.ctx.params.n * 8;
        self.steps
            .iter()
            .map(|s| {
                s.kv_ops.as_ref().map_or(0, |v| v.len() * poly_mem)
                    + s.b_ops.as_ref().map_or(0, |v| v.len() * poly_mem)
                    + s.noise_res
                        .as_ref()
                        .map_or(0, |v| v.iter().map(|c| c.len() * 8).sum::<usize>())
            })
            .sum()
    }

    /// Reset and return evaluator op counters.
    pub fn take_ops(&self) -> OpCounts {
        let c = self.ev.counts();
        self.ev.reset_counts();
        c
    }

    /// Snapshot of the accumulated online/offline compute timers.
    pub fn timers(&self) -> Timers {
        self.timers.snapshot()
    }

    /// Take (and zero) the accumulated online/offline compute timers.
    /// Under concurrent batch queries the totals interleave across queries;
    /// the single-query runner uses this per step for exact attribution.
    pub fn reset_timers(&self) -> Timers {
        self.timers.take()
    }
}

/// `k'·v` for stream position `g` of channel `ch` — the one place the
/// Conv-vs-Fc tap indexing swap lives (Conv: taps per channel; FC: taps per
/// output block), shared by the cached-operand build and the online
/// additive-operand loop so the two can never disagree.
#[inline]
fn kv_int(
    prep: &PreparedStep,
    linear: &LinearSpec,
    blocks: usize,
    block: usize,
    ch: usize,
    g: usize,
) -> i64 {
    let (blk, tap) = (g / block, g % block);
    let kq = match linear {
        LinearSpec::Conv(_) => prep.kq[ch][tap],
        LinearSpec::Fc(_) => prep.kq[blk][tap],
        LinearSpec::AvgPool { .. } => unreachable!("local steps build no operands"),
    };
    kq * prep.v_int[ch * blocks + blk]
}

/// Sum-pool additive shares (mod p) over `size×size` windows — used by both
/// parties; the mean divisor is folded into the next layer's weights.
pub fn pool_shares(
    share: &[u64],
    shape: (usize, usize, usize),
    size: usize,
    p: u64,
) -> Vec<u64> {
    let (c, h, w) = shape;
    assert_eq!(share.len(), c * h * w);
    let (oh, ow) = (h / size, w / size);
    let mut out = vec![0u64; c * oh * ow];
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0u64;
                for dy in 0..size {
                    for dx in 0..size {
                        acc = (acc + share[(ch * h + oy * size + dy) * w + ox * size + dx]) % p;
                    }
                }
                out[(ch * oh + oy) * ow + ox] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_shares_reconstructs_sum() {
        let p = 8380417u64;
        let mut rng = crate::util::rng::SplitMix64::new(4);
        let shape = (2, 4, 4);
        let total = 32;
        let a: Vec<u64> = (0..total).map(|_| rng.gen_range(p)).collect();
        let b: Vec<u64> = (0..total).map(|_| rng.gen_range(p)).collect();
        let pa = pool_shares(&a, shape, 2, p);
        let pb = pool_shares(&b, shape, 2, p);
        // Pooled (a+b), computed once — not rebuilt per index.
        let sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| (x + y) % p).collect();
        let pooled = pool_shares(&sum, shape, 2, p);
        // Reconstructed pooled value == pooled reconstructed value.
        for i in 0..pa.len() {
            assert_eq!((pa[i] + pb[i]) % p, pooled[i]);
        }
    }
}
