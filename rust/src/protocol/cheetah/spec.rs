//! The public protocol specification derived from a network architecture:
//! layer geometry, packings, fusion (linear+ReLU), pooling, and ciphertext
//! counts. Both parties hold the spec; only the server holds weights.

use super::packing::{ConvPacking, FcPacking};
use crate::nn::layers::LayerKind;
use crate::nn::Network;
use crate::phe::Params;

/// The linear kernel of one protocol step.
#[derive(Clone, Debug)]
pub enum LinearSpec {
    /// A convolutional step (one packing shared by all output channels).
    Conv(ConvPacking),
    /// A fully-connected step (input tiled per output neuron).
    Fc(FcPacking),
    /// A zero-ciphertext local pooling step: each party sum-pools its own
    /// additive share over `size × size` windows (sum-pooling commutes with
    /// additive sharing mod `p`); the mean divisor is folded into the next
    /// linear layer's weights exactly like a fused `pool_after`. No
    /// ciphertexts flow in either direction for this step.
    AvgPool {
        /// Input shape `(c, h, w)` of the activation being pooled.
        shape: (usize, usize, usize),
        /// Pool window side length (stride equals the window).
        size: usize,
    },
}

impl LinearSpec {
    /// Slot-stream length of the expanded input `x'`.
    pub fn stream_len(&self) -> usize {
        match self {
            LinearSpec::Conv(p) => p.len,
            LinearSpec::Fc(p) => p.len,
            LinearSpec::AvgPool { .. } => 0,
        }
    }

    /// Number of client→server input ciphertexts.
    pub fn num_in_cts(&self, n: usize) -> usize {
        self.stream_len().div_ceil(n)
    }

    /// Output channels that need separate multipliers (1 for FC).
    pub fn num_channels(&self) -> usize {
        match self {
            LinearSpec::Conv(p) => p.out_shape.0,
            LinearSpec::Fc(_) => 1,
            LinearSpec::AvgPool { shape, .. } => shape.0,
        }
    }

    /// Blocks (outputs) per channel.
    pub fn blocks_per_channel(&self) -> usize {
        match self {
            LinearSpec::Conv(p) => p.n_pos,
            LinearSpec::Fc(p) => p.n_o,
            LinearSpec::AvgPool { shape, size } => (shape.1 / size) * (shape.2 / size),
        }
    }

    /// Taps per block.
    pub fn block_len(&self) -> usize {
        match self {
            LinearSpec::Conv(p) => p.block,
            LinearSpec::Fc(p) => p.n_i,
            LinearSpec::AvgPool { .. } => 0,
        }
    }

    /// Total outputs (`c_o·oh·ow` or `n_o`).
    pub fn num_outputs(&self) -> usize {
        self.num_channels() * self.blocks_per_channel()
    }

    /// Server→client ciphertexts (one stream per channel).
    pub fn num_out_cts(&self, n: usize) -> usize {
        self.num_channels() * self.num_in_cts(n)
    }

    /// Ciphertexts holding the recovery output / ID vectors
    /// (output-indexed packing). Zero for local steps.
    pub fn num_recovery_cts(&self, n: usize) -> usize {
        match self {
            LinearSpec::AvgPool { .. } => 0,
            _ => self.num_outputs().div_ceil(n),
        }
    }

    /// Expand a flat share/input into the slot stream (the `T` transform).
    pub fn expand_u64(&self, input: &[u64]) -> Vec<u64> {
        match self {
            LinearSpec::Conv(p) => p.expand(input),
            LinearSpec::Fc(p) => p.expand(input),
            LinearSpec::AvgPool { .. } => Vec::new(),
        }
    }

    /// [`LinearSpec::expand_u64`] for signed values (plaintext mirrors).
    pub fn expand_i64(&self, input: &[i64]) -> Vec<i64> {
        match self {
            LinearSpec::Conv(p) => p.expand(input),
            LinearSpec::Fc(p) => p.expand(input),
            LinearSpec::AvgPool { .. } => Vec::new(),
        }
    }
}

/// One fused protocol step: linear [+ ReLU] [+ pool-after].
#[derive(Clone, Debug)]
pub struct StepSpec {
    /// Index of the linear layer in the source `Network`.
    pub layer_idx: usize,
    /// The step's linear kernel and packing.
    pub linear: LinearSpec,
    /// Fused ReLU (every step except possibly the last).
    pub relu: bool,
    /// Mean-pool (as share-domain *sum*-pool; the divisor is absorbed into
    /// the next layer's weights) applied to the activation after ReLU.
    pub pool_after: Option<usize>,
    /// Identity skip connection: after the ReLU recovery, both parties add
    /// their *saved input shares* of this step back onto the new activation
    /// shares (`x ← ReLU(linear(x)) + x`, element-wise mod `p`). Requires a
    /// fused ReLU and a shape-preserving linear layer; never combined with
    /// `pool_after`.
    pub residual_add: bool,
    /// Input shape of this step.
    pub in_shape: (usize, usize, usize),
    /// Activation shape after the linear+ReLU (before pooling).
    pub out_shape: (usize, usize, usize),
    /// Divisor inherited from preceding pools (weights are pre-divided).
    pub weight_div: f64,
}

impl StepSpec {
    /// True for steps that exchange no ciphertexts — both parties transform
    /// their own shares locally (currently only [`LinearSpec::AvgPool`]).
    pub fn is_local(&self) -> bool {
        matches!(self.linear, LinearSpec::AvgPool { .. })
    }
}

/// Why a network cannot be compiled into a protocol spec. Surfaced as a
/// typed error (through `EngineBuilder` and the serve subsystem) instead of
/// a panic, so a malformed architecture drops the request rather than
/// killing a serving worker thread.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpecError {
    /// A ReLU or pool appears without a preceding linear layer.
    UnsupportedLayerOrder {
        /// Index of the offending layer.
        index: usize,
        /// Debug-rendered layer kind.
        kind: String,
    },
    /// The network contains no linear (Conv/FC) layer at all.
    NoLinearLayers,
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::UnsupportedLayerOrder { index, kind } => write!(
                f,
                "unsupported layer order: {kind} at index {index} has no preceding linear layer"
            ),
            SpecError::NoLinearLayers => write!(f, "network has no linear layers"),
        }
    }
}

impl std::error::Error for SpecError {}

/// The full protocol spec for a network.
#[derive(Clone, Debug)]
pub struct ProtocolSpec {
    /// The fused protocol steps, in execution order.
    pub steps: Vec<StepSpec>,
    /// The network's input shape `(c, h, w)`.
    pub input_shape: (usize, usize, usize),
}

impl ProtocolSpec {
    /// Compile a network into protocol steps. Supported patterns:
    /// `Linear [→ ReLU [→ ResidualAdd]] [→ MeanPool]` (the fused step), plus
    /// a *standalone* `MeanPool` which becomes a zero-ciphertext
    /// [`LinearSpec::AvgPool`] step (both parties pool their shares
    /// locally; it cannot be the last step). A `ResidualAdd` needs a fused
    /// ReLU and a shape-preserving linear layer, and is never combined with
    /// a fused pool. Anything else is a typed [`SpecError`], not a panic.
    pub fn compile(net: &Network) -> Result<Self, SpecError> {
        let mut steps = Vec::new();
        let (mut c, mut h, mut w) = net.input_shape;
        let mut i = 0;
        let mut pending_div = 1.0f64;
        while i < net.layers.len() {
            let layer = &net.layers[i];
            match layer.kind {
                LayerKind::Conv2d { .. } | LayerKind::Fc { .. } => {
                    let in_shape = (c, h, w);
                    let linear = match layer.kind {
                        LayerKind::Conv2d { .. } => {
                            LinearSpec::Conv(ConvPacking::new(layer, in_shape))
                        }
                        _ => LinearSpec::Fc(FcPacking::new(layer, c * h * w)),
                    };
                    let out_shape = layer.out_shape(c, h, w);
                    let mut relu = false;
                    let mut pool_after = None;
                    let mut residual_add = false;
                    let mut j = i + 1;
                    if j < net.layers.len() && net.layers[j].kind == LayerKind::Relu {
                        relu = true;
                        j += 1;
                    }
                    if j < net.layers.len() && net.layers[j].kind == LayerKind::ResidualAdd {
                        // Post-activation identity skip: both parties add
                        // their saved input shares, which only reconstructs
                        // correctly when the shapes match and a ReLU
                        // recovery produced fresh activation shares.
                        if !relu || out_shape != in_shape {
                            return Err(SpecError::UnsupportedLayerOrder {
                                index: j,
                                kind: format!("{:?}", net.layers[j].kind),
                            });
                        }
                        residual_add = true;
                        j += 1;
                    }
                    let mut post_shape = out_shape;
                    if !residual_add {
                        if let Some(LayerKind::MeanPool { size }) =
                            net.layers.get(j).map(|l| l.kind.clone())
                        {
                            pool_after = Some(size);
                            post_shape = (out_shape.0, out_shape.1 / size, out_shape.2 / size);
                            j += 1;
                        }
                    }
                    steps.push(StepSpec {
                        layer_idx: i,
                        linear,
                        relu,
                        pool_after,
                        residual_add,
                        in_shape,
                        out_shape,
                        weight_div: pending_div,
                    });
                    pending_div = pool_after.map(|s| (s * s) as f64).unwrap_or(1.0);
                    (c, h, w) = post_shape;
                    i = j;
                }
                LayerKind::MeanPool { size } => {
                    // Standalone pool (no preceding fused linear): a local
                    // share-domain sum-pool step; the divisor composes into
                    // the next linear layer's weight pre-division.
                    let in_shape = (c, h, w);
                    let out_shape = (c, h / size, w / size);
                    steps.push(StepSpec {
                        layer_idx: i,
                        linear: LinearSpec::AvgPool { shape: in_shape, size },
                        relu: false,
                        pool_after: None,
                        residual_add: false,
                        in_shape,
                        out_shape,
                        weight_div: 1.0,
                    });
                    pending_div *= (size * size) as f64;
                    (c, h, w) = out_shape;
                    i += 1;
                }
                LayerKind::Relu | LayerKind::ResidualAdd => {
                    return Err(SpecError::UnsupportedLayerOrder {
                        index: i,
                        kind: format!("{:?}", layer.kind),
                    });
                }
            }
        }
        if steps.is_empty() {
            return Err(SpecError::NoLinearLayers);
        }
        if steps.last().is_some_and(|s| s.is_local()) {
            // A trailing local pool has no linear step left to absorb its
            // divisor (and no obscured result to reveal).
            let last = steps.last().unwrap();
            return Err(SpecError::UnsupportedLayerOrder {
                index: last.layer_idx,
                kind: "MeanPool (trailing)".into(),
            });
        }
        Ok(Self { steps, input_shape: net.input_shape })
    }

    /// Index of the last step (its result is revealed obscured — `f^OMI`).
    pub fn last_idx(&self) -> usize {
        self.steps.len() - 1
    }

    /// Whether step `si` has a ReLU recovery round: every hidden step
    /// except the zero-ciphertext local ones.
    pub fn has_recovery(&self, si: usize) -> bool {
        si != self.last_idx() && !self.steps[si].is_local()
    }

    /// Total online communication estimate in bytes (fresh c2s cts, 2-poly
    /// s2c cts, 2-poly recovery cts) — used for quick capacity planning;
    /// the benchmarks meter actual serialized bytes.
    pub fn estimate_online_bytes(&self, params: &Params) -> u64 {
        use crate::phe::serial::ciphertext_bytes;
        let n = params.n;
        let mut total = 0u64;
        for (idx, s) in self.steps.iter().enumerate() {
            total += (s.linear.num_in_cts(n) as u64) * ciphertext_bytes(params, true) as u64;
            total += (s.linear.num_out_cts(n) as u64) * ciphertext_bytes(params, false) as u64;
            if self.has_recovery(idx) {
                total +=
                    (s.linear.num_recovery_cts(n) as u64) * ciphertext_bytes(params, false) as u64;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::NetworkArch;

    #[test]
    fn compile_net_a() {
        let net = Network::build(NetworkArch::NetA, 1);
        let spec = ProtocolSpec::compile(&net).expect("valid network");
        assert_eq!(spec.steps.len(), 3); // conv+relu, fc+relu, fc
        assert!(spec.steps[0].relu && spec.steps[1].relu && !spec.steps[2].relu);
        assert!(spec.steps.iter().all(|s| s.pool_after.is_none()));
        assert!(matches!(spec.steps[0].linear, LinearSpec::Conv(_)));
        assert!(matches!(spec.steps[2].linear, LinearSpec::Fc(_)));
    }

    #[test]
    fn compile_net_b_with_pools() {
        let net = Network::build(NetworkArch::NetB, 1);
        let spec = ProtocolSpec::compile(&net).expect("valid network");
        assert_eq!(spec.steps.len(), 4);
        assert_eq!(spec.steps[0].pool_after, Some(2));
        assert_eq!(spec.steps[1].pool_after, Some(2));
        // The pool divisor lands on the *next* step's weights.
        assert_eq!(spec.steps[0].weight_div, 1.0);
        assert_eq!(spec.steps[1].weight_div, 4.0);
        assert_eq!(spec.steps[2].weight_div, 4.0);
        assert_eq!(spec.steps[3].weight_div, 1.0);
    }

    #[test]
    fn compile_big_nets() {
        for arch in [NetworkArch::AlexNet, NetworkArch::Vgg16] {
            let net = Network::build_scaled(arch, 1, 0.125);
            let spec = ProtocolSpec::compile(&net).expect("valid network");
            let n_linear = spec.steps.len();
            assert!(n_linear == 8 || n_linear == 16, "{arch:?}: {n_linear} steps");
            // Shapes chain.
            for w in spec.steps.windows(2) {
                let (c, h, wd) = w[1].in_shape;
                let (pc, mut ph, mut pw) = w[0].out_shape;
                if let Some(s) = w[0].pool_after {
                    ph /= s;
                    pw /= s;
                }
                if matches!(w[1].linear, LinearSpec::Conv(_)) {
                    assert_eq!((c, h, wd), (pc, ph, pw));
                } else {
                    assert_eq!(c * h * wd, pc * ph * pw);
                }
            }
        }
    }

    #[test]
    fn compile_netres_residual_steps() {
        let net = Network::build(NetworkArch::NetRes, 1);
        let spec = ProtocolSpec::compile(&net).expect("valid network");
        assert_eq!(spec.steps.len(), 12); // stem + 10 residual blocks + fc
        assert!(!spec.steps[0].residual_add);
        for si in 1..=10 {
            let s = &spec.steps[si];
            assert!(s.residual_add, "step {si} should carry the skip add");
            assert!(s.relu && s.pool_after.is_none());
            assert_eq!(s.in_shape, s.out_shape, "residual steps are shape-preserving");
        }
        assert!(!spec.steps[11].residual_add);
        // Residual adds are share-local: ciphertext counts are unchanged
        // relative to a plain conv step.
        assert!(spec.steps.iter().all(|s| !s.is_local()));
    }

    #[test]
    fn compile_netpool_standalone_pool() {
        let net = Network::build(NetworkArch::NetPool, 1);
        let spec = ProtocolSpec::compile(&net).expect("valid network");
        assert_eq!(spec.steps.len(), 3); // avgpool, conv+relu, fc
        let s0 = &spec.steps[0];
        assert!(s0.is_local());
        assert_eq!(s0.in_shape, (1, 28, 28));
        assert_eq!(s0.out_shape, (1, 14, 14));
        let n = 4096;
        assert_eq!(s0.linear.num_in_cts(n), 0);
        assert_eq!(s0.linear.num_out_cts(n), 0);
        assert_eq!(s0.linear.num_recovery_cts(n), 0);
        assert_eq!(s0.linear.num_outputs(), 14 * 14);
        // The pool's divisor lands on the conv's weights.
        assert_eq!(spec.steps[1].weight_div, 4.0);
        // Local steps never have a recovery round; hidden non-local do.
        assert!(!spec.has_recovery(0));
        assert!(spec.has_recovery(1));
        assert!(!spec.has_recovery(2));
    }

    #[test]
    fn malformed_residual_and_trailing_pool_are_errors() {
        use crate::nn::Layer;
        // Residual without a fused ReLU.
        let no_relu = Network {
            name: "no-relu".into(),
            input_shape: (1, 4, 4),
            layers: vec![Layer::conv(1, 3, 1, 1), Layer::residual_add(), Layer::fc(2)],
        };
        assert!(matches!(
            ProtocolSpec::compile(&no_relu),
            Err(SpecError::UnsupportedLayerOrder { index: 1, .. })
        ));
        // Residual across a shape change.
        let shape_change = Network {
            name: "shape-change".into(),
            input_shape: (1, 4, 4),
            layers: vec![
                Layer::conv(2, 3, 1, 1),
                Layer::relu(),
                Layer::residual_add(),
                Layer::fc(2),
            ],
        };
        assert!(matches!(
            ProtocolSpec::compile(&shape_change),
            Err(SpecError::UnsupportedLayerOrder { index: 2, .. })
        ));
        // A trailing standalone pool has no consumer for its divisor.
        let trailing = Network {
            name: "trailing-pool".into(),
            input_shape: (1, 4, 4),
            layers: vec![Layer::conv(1, 3, 1, 1), Layer::relu(), Layer::mean_pool(2), Layer::mean_pool(2)],
        };
        assert!(matches!(
            ProtocolSpec::compile(&trailing),
            Err(SpecError::UnsupportedLayerOrder { .. })
        ));
        // A bare ResidualAdd opening the net is an order error.
        let bare = Network {
            name: "bare-res".into(),
            input_shape: (1, 4, 4),
            layers: vec![Layer::residual_add(), Layer::fc(2)],
        };
        assert!(matches!(
            ProtocolSpec::compile(&bare),
            Err(SpecError::UnsupportedLayerOrder { index: 0, .. })
        ));
    }

    #[test]
    fn malformed_networks_are_typed_errors_not_panics() {
        use crate::nn::Layer;
        // ReLU with no preceding linear layer.
        let bad_order = Network {
            name: "bad-order".into(),
            input_shape: (1, 4, 4),
            layers: vec![Layer::relu(), Layer::fc(2)],
        };
        match ProtocolSpec::compile(&bad_order) {
            Err(super::SpecError::UnsupportedLayerOrder { index: 0, .. }) => {}
            other => panic!("expected UnsupportedLayerOrder, got {other:?}"),
        }
        // No linear layers at all.
        let empty = Network { name: "empty".into(), input_shape: (1, 4, 4), layers: vec![] };
        assert_eq!(
            ProtocolSpec::compile(&empty).unwrap_err(),
            super::SpecError::NoLinearLayers
        );
        // Errors render a human-readable message.
        let msg = ProtocolSpec::compile(&bad_order).unwrap_err().to_string();
        assert!(msg.contains("index 0"), "{msg}");
    }

    #[test]
    fn ct_count_accounting() {
        let net = Network::build(NetworkArch::NetA, 1);
        let spec = ProtocolSpec::compile(&net).expect("valid network");
        let params = Params::default_params();
        let s0 = &spec.steps[0];
        // Conv 5×5@5 stride 2 pad 2 on 28×28: n_pos = 14*14, block = 25.
        assert_eq!(s0.linear.blocks_per_channel(), 14 * 14);
        assert_eq!(s0.linear.block_len(), 25);
        assert_eq!(s0.linear.num_in_cts(params.n), (14 * 14 * 25usize).div_ceil(4096));
        assert!(spec.estimate_online_bytes(&params) > 0);
    }
}
