//! Blinding material and the polar-indicator recovery (paper §3.1, Eqs. 4–7).
//!
//! Per output block `i` the server samples:
//!
//! * a multiplicative blind `v₁ᵢ = sᵢ·2^{jᵢ}` with random sign `sᵢ` and
//!   exponent `jᵢ ∈ {-1,0,1}` — its inverse `v₂ᵢ = sᵢ·2^{-jᵢ}` is exactly
//!   representable, so `v₁v₂ = 1` with **no rounding** (the paper's
//!   approximation-free property; see `fixed` module docs),
//! * an additive noise target `δᵢ ~ U[-ε, ε]`,
//! * per-tap noise `b_{ij}` with `Σ_j b_{ij} = v₁ᵢ·δᵢ` (antithetic pairs:
//!   `b` entries are marginally uniform, bounded, and sum exactly),
//! * the polar indicator pair (Eq. 4):
//!   `(ID₁ᵢ, ID₂ᵢ) = (0, v₂ᵢ)` if `v₁ᵢ > 0`, `(v₂ᵢ, -v₂ᵢ)` if `v₁ᵢ < 0`.
//!
//! The client, holding only `y = v₁·(Con+δ)`, computes
//! `ID₁·y + ID₂·ReLU(y)` under the server's HE — which equals
//! `ReLU(Con+δ)` in every sign case (Eq. 7).

use crate::fixed::ScalePlan;
use crate::util::rng::ChaCha20Rng;

/// One block's blinding factor `v₁ = s·2^j`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Blind {
    /// Sign: +1 or -1.
    pub s: i8,
    /// Exponent in {-1, 0, 1}.
    pub j: i8,
}

impl Blind {
    /// Sample a uniform blind over the 6-element support `{±1}×{-1,0,1}`.
    pub fn sample(rng: &mut ChaCha20Rng) -> Self {
        let s = if rng.gen_range(2) == 0 { 1 } else { -1 };
        let j = rng.gen_range(3) as i8 - 1;
        Self { s, j }
    }

    /// Identity blind (used for the final layer, where the paper's ideal
    /// functionality reveals the obscured linear result under one shared v).
    pub fn identity() -> Self {
        Self { s: 1, j: 0 }
    }

    /// `v₁` as a fixed-point integer at `plan.v`.
    pub fn v1_int(&self, plan: &ScalePlan) -> i64 {
        let base = plan.v.frac_bits as i64 + self.j as i64;
        debug_assert!(base >= 0);
        (self.s as i64) * (1i64 << base)
    }

    /// `v₂ = 1/v₁` as a fixed-point integer at `plan.id`.
    pub fn v2_int(&self, plan: &ScalePlan) -> i64 {
        let base = plan.id.frac_bits as i64 - self.j as i64;
        debug_assert!(base >= 0);
        (self.s as i64) * (1i64 << base)
    }

    /// Polar indicator pair (Eq. 4) as fixed-point integers at `plan.id`.
    pub fn indicator(&self, plan: &ScalePlan) -> (i64, i64) {
        let v2 = self.v2_int(plan);
        if self.s > 0 {
            (0, v2)
        } else {
            (v2, -v2)
        }
    }
}

/// Per-tap additive noise summing exactly to `target` per block, with each
/// entry bounded by `±(bound + |target|)`. Antithetic construction: pairs
/// `(u, -u)` plus the target folded into the first tap.
pub fn sample_block_noise(
    block: usize,
    target: i64,
    bound: i64,
    rng: &mut ChaCha20Rng,
) -> Vec<i64> {
    let mut b = vec![0i64; block];
    let mut i = 1;
    while i + 1 < block {
        let u = rng.gen_range(2 * bound as u64 + 1) as i64 - bound;
        b[i] = u;
        b[i + 1] = -u;
        i += 2;
    }
    if block > 1 {
        // Pair tap 0 with the leftover odd tap (if any) so tap 0 is also
        // marginally random.
        let u = rng.gen_range(2 * bound as u64 + 1) as i64 - bound;
        b[0] = u + target;
        if i < block {
            b[i] = -u;
        } else {
            b[1] -= u; // fold into an existing entry, preserving the sum
        }
    } else {
        b[0] = target;
    }
    debug_assert_eq!(b.iter().sum::<i64>(), target);
    b
}

/// The client-side scrambled nonlinearity (plaintext mirror of the HE
/// recovery; also the reference for the L1 Pallas kernel `relu_recover`):
/// given centered `y` at `plan.y`, returns `(y_clamped, relu(y_clamped))`.
pub fn client_y_pair(y_int_sum_scale: i64, plan: &ScalePlan) -> (i64, i64) {
    // Requantize from the product scale (x+k+v) down to plan.y.
    let shift = (plan.x.frac_bits + plan.k.frac_bits + plan.v.frac_bits) - plan.y.frac_bits;
    let half = 1i64 << (shift - 1);
    let y = (y_int_sum_scale + half) >> shift;
    let clamp = plan.y.quantize(plan.y_max);
    let y = y.clamp(-clamp, clamp);
    (y, y.max(0))
}

/// Plaintext recovery check (Eq. 6/7): `ID₁·y + ID₂·ReLU(y)` at scale
/// `plan.y + plan.id == plan.x`.
pub fn recover_plain(y: i64, relu_y: i64, blind: &Blind, plan: &ScalePlan) -> i64 {
    let (id1, id2) = blind.indicator(plan);
    id1 * y + id2 * relu_y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn plan() -> ScalePlan {
        ScalePlan::default_plan()
    }

    #[test]
    fn blind_inverse_is_exact() {
        let plan = plan();
        for s in [1i8, -1] {
            for j in [-1i8, 0, 1] {
                let b = Blind { s, j };
                let v1 = b.v1_int(&plan);
                let v2 = b.v2_int(&plan);
                // v1·v2 must equal exactly 1.0 at the combined scale.
                let one = 1i64 << (plan.v.frac_bits + plan.id.frac_bits);
                assert_eq!(v1 * v2, one, "s={s} j={j}");
            }
        }
    }

    #[test]
    fn recovery_all_four_sign_cases() {
        // Eq. 7: the recovery equals ReLU(Con+δ) in all four cases of
        // (sign(v1), sign(Con+δ)).
        let plan = plan();
        let prod_scale = plan.x.mul(plan.k).mul(plan.v);
        for s in [1i8, -1] {
            for j in [-1i8, 0, 1] {
                for con_val in [1.25f64, -1.25, 0.0, 0.015625, -0.015625, 2.5, -2.5] {
                    let blind = Blind { s, j };
                    // y = v1·(Con+δ) at the product scale.
                    let v1_val = (s as f64) * 2f64.powi(j as i32);
                    let y_prod = prod_scale.quantize(v1_val * con_val);
                    let (y, relu_y) = client_y_pair(y_prod, &plan);
                    let rec = recover_plain(y, relu_y, &blind, &plan);
                    let got = plan.x.dequantize(rec);
                    // The client clamps |y| at y_max, so the effective
                    // pre-activation clamp is y_max/|v1|.
                    let clamp = plan.y_max / v1_val.abs();
                    let want = con_val.clamp(-clamp, clamp).max(0.0);
                    assert!(
                        (got - want).abs() < 0.05,
                        "s={s} j={j} con={con_val}: got {got} want {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn recovery_exact_for_representable_values() {
        // With v=±2^j and inputs exactly on the plan.y grid, recovery is
        // bit-exact (the approximation-free property).
        let plan = plan();
        let prod_scale = plan.x.mul(plan.k).mul(plan.v);
        for s in [1i8, -1] {
            for j in [-1i8, 0, 1] {
                let blind = Blind { s, j };
                let con = 1.25f64; // exactly representable at plan.y
                let v1_val = (s as f64) * 2f64.powi(j as i32);
                let y_prod = prod_scale.quantize(v1_val * con);
                let (y, relu_y) = client_y_pair(y_prod, &plan);
                let rec = recover_plain(y, relu_y, &blind, &plan);
                assert_eq!(rec, plan.x.quantize(con), "s={s} j={j}");
            }
        }
    }

    #[test]
    fn block_noise_sums_to_target() {
        proptest::check_with_rng(31, 100, |rng| {
            let mut crng = crate::util::rng::ChaCha20Rng::from_u64_seed(rng.next_u64());
            let block = 1 + rng.gen_range(40) as usize;
            let target = rng.gen_i64_range(-5000, 5000);
            let bound = 1 << 18;
            let b = sample_block_noise(block, target, bound, &mut crng);
            if b.len() != block {
                return Err("wrong length".into());
            }
            if b.iter().sum::<i64>() != target {
                return Err(format!("sum {} != target {target}", b.iter().sum::<i64>()));
            }
            if b.iter().any(|&x| x.abs() > 2 * bound + target.abs()) {
                return Err("entry out of bound".into());
            }
            Ok(())
        });
    }

    #[test]
    fn block_noise_is_not_constant() {
        let mut rng = crate::util::rng::ChaCha20Rng::from_u64_seed(8);
        let b = sample_block_noise(16, 0, 1 << 18, &mut rng);
        assert!(b.iter().filter(|&&x| x != 0).count() >= 8, "noise looks degenerate: {b:?}");
    }

    #[test]
    fn blind_sampling_covers_support() {
        let mut rng = crate::util::rng::ChaCha20Rng::from_u64_seed(9);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            let b = Blind::sample(&mut rng);
            assert!(b.s == 1 || b.s == -1);
            assert!((-1..=1).contains(&b.j));
            seen.insert((b.s, b.j));
        }
        assert_eq!(seen.len(), 6, "all 6 blinds should appear");
    }
}
