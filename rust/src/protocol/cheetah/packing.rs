//! Slot packings for the obscure linear computation (paper §3.1–§3.3).
//!
//! The transform `x → x'` lays the input taps of every linear-output
//! *block* contiguously in SIMD slots, so that after the element-wise
//! multiply `x'∘k'∘v + b` the **client** can finish each output with a
//! plain block sum — no ciphertext permutations, ever.
//!
//! * [`ConvPacking`]: block = the `c_i·r²` input taps of one output
//!   position; the spatial packing is shared by all `c_o` output channels
//!   (the kernel multiplier differs per channel, the ciphertexts don't).
//! * [`FcPacking`]: block = the whole input vector, one block per output
//!   neuron (`x'` is the input tiled `n_o` times).
//!
//! Blocks may straddle ciphertext boundaries: the client sums *ranges of a
//! concatenated slot stream*, so no alignment padding is needed.

use crate::nn::layers::{Layer, LayerKind};
use crate::par;

/// Where tap `t` of a block comes from in the flat input vector.
/// `None` encodes zero-padding taps.
pub type TapSource = Option<usize>;

/// Packing for a convolutional layer.
#[derive(Clone, Debug)]
pub struct ConvPacking {
    /// Input shape `(c_i, h, w)`.
    pub in_shape: (usize, usize, usize),
    /// Output shape `(c_o, oh, ow)`.
    pub out_shape: (usize, usize, usize),
    /// Kernel side length `r`.
    pub kernel: usize,
    /// Convolution stride.
    pub stride: usize,
    /// Zero padding.
    pub pad: usize,
    /// Taps per block: `c_i · r²`.
    pub block: usize,
    /// Output positions per channel: `oh · ow`.
    pub n_pos: usize,
    /// Slot-stream length: `n_pos · block`.
    pub len: usize,
}

impl ConvPacking {
    /// Derive the packing from a Conv2d layer and its input shape.
    pub fn new(layer: &Layer, in_shape: (usize, usize, usize)) -> Self {
        let LayerKind::Conv2d { kernel, stride, pad, .. } = layer.kind else {
            panic!("ConvPacking requires a Conv2d layer");
        };
        let (c, h, w) = in_shape;
        let out_shape = layer.out_shape(c, h, w);
        let n_pos = out_shape.1 * out_shape.2;
        let block = c * kernel * kernel;
        Self { in_shape, out_shape, kernel, stride, pad, block, n_pos, len: n_pos * block }
    }

    /// Number of ciphertexts for `n` slots per ciphertext.
    pub fn num_cts(&self, n: usize) -> usize {
        self.len.div_ceil(n)
    }

    /// Source of tap `t` at output position `pos`.
    #[inline]
    pub fn tap_source(&self, pos: usize, t: usize) -> TapSource {
        let (c_i, h, w) = self.in_shape;
        let ow = self.out_shape.2;
        let (oy, ox) = (pos / ow, pos % ow);
        let i = t / (self.kernel * self.kernel);
        let rem = t % (self.kernel * self.kernel);
        let (ky, kx) = (rem / self.kernel, rem % self.kernel);
        debug_assert!(i < c_i);
        let y = (oy * self.stride + ky) as isize - self.pad as isize;
        let x = (ox * self.stride + kx) as isize - self.pad as isize;
        if y < 0 || x < 0 || y >= h as isize || x >= w as isize {
            None
        } else {
            Some((i * h + y as usize) * w + x as usize)
        }
    }

    /// The `T` transform: expand a flat input (length `c·h·w`) into the
    /// slot stream (length `len`). Works on any copyable scalar — in the
    /// protocol this is applied to plaintext inputs *and* to mod-p shares
    /// (`T` is linear, so it commutes with secret sharing).
    pub fn expand<T: Copy + Default + Send + Sync>(&self, input: &[T]) -> Vec<T> {
        let (c, h, w) = self.in_shape;
        assert_eq!(input.len(), c * h * w, "input length mismatch");
        let mut out = vec![T::default(); self.len];
        // Each output position owns one disjoint block of the slot stream.
        // One position's block is tiny (c·r² copies), so chunks coalesce
        // several positions to amortize the per-chunk dispatch handshake —
        // values are identical at any grouping and thread count.
        let per_chunk = (2048 / self.block).max(1);
        par::for_each_chunk_mut(&mut out, self.block * per_chunk, |ci, chunk| {
            for (k, block) in chunk.chunks_mut(self.block).enumerate() {
                let pos = ci * per_chunk + k;
                for (t, slot) in block.iter_mut().enumerate() {
                    if let Some(src) = self.tap_source(pos, t) {
                        *slot = input[src];
                    }
                }
            }
        });
        out
    }

    /// Kernel weights (quantized via `quant`) for output channel `o`, laid
    /// out over the slot stream and scaled by the per-position blinding
    /// `v_int[pos]`: slot `pos·block + t` gets `k_q[o][t] · v_int[pos]`.
    pub fn kv_multiplier(
        &self,
        layer: &Layer,
        o: usize,
        v_int: &[i64],
        quant: impl Fn(f64) -> i64,
    ) -> Vec<i64> {
        assert_eq!(v_int.len(), self.n_pos);
        let (c_i, _, _) = self.in_shape;
        let r = self.kernel;
        // Quantize the c_i·r² kernel taps for this output channel once.
        let kq: Vec<i64> = (0..self.block)
            .map(|t| {
                let i = t / (r * r);
                let rem = t % (r * r);
                quant(layer.conv_w(c_i, r, o, i, rem / r, rem % r))
            })
            .collect();
        let mut out = vec![0i64; self.len];
        par::for_each_chunk_mut(&mut out, self.block, |pos, chunk| {
            for (t, slot) in chunk.iter_mut().enumerate() {
                *slot = kq[t] * v_int[pos];
            }
        });
        out
    }
}

/// Packing for a fully-connected layer.
#[derive(Clone, Debug)]
pub struct FcPacking {
    /// Input features.
    pub n_i: usize,
    /// Output features.
    pub n_o: usize,
    /// Slot-stream length: `n_o · n_i`.
    pub len: usize,
}

impl FcPacking {
    /// Derive the packing from an Fc layer and its input length.
    pub fn new(layer: &Layer, in_len: usize) -> Self {
        let LayerKind::Fc { out_features } = layer.kind else {
            panic!("FcPacking requires an Fc layer");
        };
        Self { n_i: in_len, n_o: out_features, len: out_features * in_len }
    }

    /// Number of ciphertexts for `n` slots per ciphertext.
    pub fn num_cts(&self, n: usize) -> usize {
        self.len.div_ceil(n)
    }

    /// Taps per block (the whole input vector).
    pub fn block_len(&self) -> usize {
        self.n_i
    }

    /// `T`: tile the input vector `n_o` times.
    pub fn expand<T: Copy>(&self, input: &[T]) -> Vec<T> {
        assert_eq!(input.len(), self.n_i, "input length mismatch");
        let mut out = Vec::with_capacity(self.len);
        for _ in 0..self.n_o {
            out.extend_from_slice(input);
        }
        out
    }

    /// Weight multiplier over the slot stream, scaled by per-output-block
    /// blinding: slot `o·n_i + j` gets `w_q[o][j] · v_int[o]`.
    pub fn kv_multiplier(
        &self,
        layer: &Layer,
        v_int: &[i64],
        quant: impl (Fn(f64) -> i64) + Sync,
    ) -> Vec<i64> {
        assert_eq!(v_int.len(), self.n_o);
        let mut out = vec![0i64; self.len];
        par::for_each_chunk_mut(&mut out, self.n_i, |o, chunk| {
            for (j, slot) in chunk.iter_mut().enumerate() {
                *slot = quant(layer.fc_w(self.n_i, o, j)) * v_int[o];
            }
        });
        out
    }
}

/// Sum contiguous blocks of a concatenated slot stream: block `i` is
/// `stream[i·block .. (i+1)·block]`. This is the client-side plaintext sum
/// that replaces GAZELLE's rotate-and-sum — the hot loop mirrored by the
/// L1 Pallas kernel `obscure_dot`.
pub fn block_sums(stream: &[i64], block: usize, n_blocks: usize) -> Vec<i64> {
    assert!(stream.len() >= block * n_blocks, "stream too short");
    (0..n_blocks).map(|i| stream[i * block..(i + 1) * block].iter().sum()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::ScalePlan;
    use crate::nn::layers::forward_layer;
    use crate::nn::Tensor;
    use crate::util::rng::SplitMix64;

    /// End-to-end packing property: expand ∘ multiply ∘ block-sum ==
    /// quantized convolution, for random shapes.
    #[test]
    fn conv_packing_computes_convolution() {
        let plan = ScalePlan::default_plan();
        let mut rng = SplitMix64::new(21);
        for (c_i, c_o, hw, r, stride, pad) in
            [(1, 1, 4, 3, 1, 1), (2, 3, 6, 3, 1, 1), (1, 5, 8, 5, 2, 2), (3, 2, 5, 1, 1, 0)]
        {
            let mut layer = Layer::conv(c_o, r, stride, pad);
            layer.init_weights(c_i, hw, hw, &mut rng);
            let packing = ConvPacking::new(&layer, (c_i, hw, hw));
            let input = Tensor::from_vec(
                (0..c_i * hw * hw).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(),
                c_i,
                hw,
                hw,
            );
            let float_out = forward_layer(&layer, &input);

            let xq: Vec<i64> = input.data.iter().map(|&v| plan.quant_x(v)).collect();
            let expanded = packing.expand(&xq);
            let v_one = vec![1i64 << plan.v.frac_bits; packing.n_pos]; // v = 1.0
            for o in 0..c_o {
                let kv = packing.kv_multiplier(&layer, o, &v_one, |w| plan.quant_k(w));
                let prods: Vec<i64> =
                    expanded.iter().zip(&kv).map(|(&x, &k)| x * k).collect();
                let sums = block_sums(&prods, packing.block, packing.n_pos);
                let scale = plan.x.mul(plan.k).mul(plan.v);
                for pos in 0..packing.n_pos {
                    let got = scale.dequantize(sums[pos]);
                    let want = float_out.data[o * packing.n_pos + pos];
                    assert!(
                        (got - want).abs() < 0.15,
                        "conv mismatch: cfg=({c_i},{c_o},{hw},{r}) o={o} pos={pos} got={got} want={want}"
                    );
                }
            }
        }
    }

    #[test]
    fn fc_packing_computes_dot_products() {
        let plan = ScalePlan::default_plan();
        let mut rng = SplitMix64::new(22);
        let (n_i, n_o) = (32, 7);
        let mut layer = Layer::fc(n_o);
        layer.init_weights(1, 1, n_i, &mut rng);
        let packing = FcPacking::new(&layer, n_i);
        let input: Vec<f64> = (0..n_i).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect();
        let float_out = forward_layer(&layer, &Tensor::from_flat(input.clone()));

        let xq: Vec<i64> = input.iter().map(|&v| plan.quant_x(v)).collect();
        let expanded = packing.expand(&xq);
        let v_one = vec![1i64 << plan.v.frac_bits; n_o];
        let kv = packing.kv_multiplier(&layer, &v_one, |w| plan.quant_k(w));
        let prods: Vec<i64> = expanded.iter().zip(&kv).map(|(&x, &k)| x * k).collect();
        let sums = block_sums(&prods, packing.block_len(), n_o);
        let scale = plan.x.mul(plan.k).mul(plan.v);
        for o in 0..n_o {
            let got = scale.dequantize(sums[o]);
            assert!((got - float_out.data[o]).abs() < 0.1, "fc mismatch at {o}");
        }
    }

    #[test]
    fn expand_is_linear_mod_p() {
        // T(a) + T(b) == T(a+b) slot-wise — the property that lets the
        // protocol run on additive shares.
        let mut rng = SplitMix64::new(23);
        let layer = {
            let mut l = Layer::conv(2, 3, 1, 1);
            l.init_weights(1, 5, 5, &mut rng);
            l
        };
        let packing = ConvPacking::new(&layer, (1, 5, 5));
        let p = 8380417u64;
        let a: Vec<u64> = (0..25).map(|_| rng.gen_range(p)).collect();
        let b: Vec<u64> = (0..25).map(|_| rng.gen_range(p)).collect();
        let sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| (x + y) % p).collect();
        let ta = packing.expand(&a);
        let tb = packing.expand(&b);
        let tsum = packing.expand(&sum);
        for i in 0..packing.len {
            assert_eq!((ta[i] + tb[i]) % p, tsum[i]);
        }
    }

    #[test]
    fn paper_example_block_structure() {
        // The paper's §3.1 example: 2×2 input, 3×3 kernel (pad 1) → four
        // blocks of 9 taps; Con_1..Con_4. Verify tap sources match Fig. 4
        // (Con_1 touches k(2,2),k(2,3),k(3,2),k(3,3) against the 4 inputs).
        let layer = Layer::conv(1, 3, 1, 1);
        let packing = ConvPacking::new(&layer, (1, 2, 2));
        assert_eq!(packing.n_pos, 4);
        assert_eq!(packing.block, 9);
        // Output position 0 == Con_1: non-padding taps are exactly the
        // kernel entries (1,1),(1,2),(2,1),(2,2) [0-indexed] hitting inputs
        // x(0,0),x(0,1),x(1,0),x(1,1).
        let live: Vec<(usize, usize)> = (0..9)
            .filter_map(|t| packing.tap_source(0, t).map(|src| (t, src)))
            .collect();
        assert_eq!(live, vec![(4, 0), (5, 1), (7, 2), (8, 3)]);
    }

    #[test]
    fn ct_counts() {
        let layer = Layer::conv(5, 5, 1, 0);
        let packing = ConvPacking::new(&layer, (1, 28, 28));
        assert_eq!(packing.block, 25);
        assert_eq!(packing.n_pos, 24 * 24);
        assert_eq!(packing.len, 24 * 24 * 25);
        assert_eq!(packing.num_cts(4096), (24 * 24 * 25usize).div_ceil(4096));
    }

    #[test]
    fn block_sum_ranges() {
        let stream = vec![1i64, 2, 3, 4, 5, 6];
        assert_eq!(block_sums(&stream, 2, 3), vec![3, 7, 11]);
        assert_eq!(block_sums(&stream, 3, 2), vec![6, 15]);
    }
}
