//! The CHEETAH client: encrypts its expanded activation share, finishes the
//! obscured linear transformation with plaintext block sums, computes the
//! scrambled nonlinearity, and recovers the server-encrypted exact ReLU via
//! the polar indicators (paper §3.1 step 3).
//!
//! The client's hot loops — the per-block sum of the decrypted obscured
//! products and the `ID₁∘y + ID₂∘ReLU(y)` recovery — are exactly what the
//! L1 Pallas kernels (`obscure_dot`, `relu_recover`) implement for the
//! accelerated plaintext path; golden vectors tie the two together.
//!
//! # Per-query RNG stream isolation
//!
//! Everything RNG-consuming on the client is **per query**: the encryption
//! randomness in [`CheetahClient::step_send`] and the fresh shares `s₁`
//! drawn in [`CheetahClient::step_receive`]. So that independent queries
//! can score concurrently (batch-level parallelism) while staying
//! bit-identical to the looped sequential path, each query owns its own
//! ChaCha20 stream, domain-separated by `(base seed, query index)`:
//!
//! * the 32-byte ChaCha20 key is expanded from the client's `u64` seed,
//! * **stream 0** (the ChaCha20 96-bit-nonce word pair) belongs to key
//!   generation at construction,
//! * **stream `1 + query_index`** belongs to query `query_index`.
//!
//! Streams of one key never overlap (distinct nonces ⇒ disjoint
//! keystreams), so a query's draws do not depend on how many queries ran
//! before it on other threads — the draw sequence for query *i* is the same
//! whether the batch runs on 1 thread or 8, in a loop or fanned out.
//! Within one query the draws stay strictly sequential (share draws `s₁`
//! are pulled up front, in ciphertext-major slot-minor order).

use super::blinding::client_y_pair;
use super::packing::block_sums;
use super::spec::{LinearSpec, ProtocolSpec};
use crate::fixed::ScalePlan;
use crate::nn::Tensor;
use crate::par;
use crate::phe::{Ciphertext, Context, Encryptor, Evaluator, OpCounts};
use crate::util::rng::ChaCha20Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// ChaCha20 stream id owned by client key generation (see module docs).
const KEYGEN_STREAM: u64 = 0;
/// First ChaCha20 stream id owned by queries: query `i` draws from stream
/// `QUERY_STREAM_BASE + i`, disjoint from the keygen stream and from every
/// other query's stream.
const QUERY_STREAM_BASE: u64 = 1;

/// Per-query client state: the share chain, the last layer's blinded
/// logits, this query's domain-separated RNG stream, and its attributed
/// client compute time.
///
/// Queries are independent values: a batch scores many `ClientQuery`s
/// concurrently against one shared [`CheetahClient`]
/// ([`super::runner::CheetahRunner::infer_batch`]), and the sequential
/// wrappers ([`CheetahClient::begin_query`] …) drive exactly one.
pub struct ClientQuery {
    /// Client's additive share (mod p) of the current activation.
    share: Vec<u64>,
    /// Blinded logits from the last layer (product scale).
    last_y: Vec<i64>,
    /// This query's RNG stream (`(base seed, query index)`-derived).
    rng: ChaCha20Rng,
    /// Client-side compute attributed to this query.
    online: Duration,
}

impl ClientQuery {
    /// The client's current additive share (mod p).
    pub fn share(&self) -> &[u64] {
        &self.share
    }
}

/// The client side of the CHEETAH protocol. Owns a shared `Arc<Context>`
/// (no lifetime parameter), so networked clients and engines can hold it
/// alongside the context without borrow gymnastics.
///
/// Like the server, scoring is **stateless** (`&self`): all per-query
/// state lives in a [`ClientQuery`] threaded through the `*_with` methods,
/// so one client (one key) can drive many queries concurrently. The
/// `&mut self` wrappers keep a single internal query for the classic call
/// sequence ([`CheetahClient::begin_query`] → [`CheetahClient::step_send`]
/// → [`CheetahClient::step_receive`] → [`CheetahClient::logits`]).
pub struct CheetahClient {
    /// Shared PHE context (parameters, encoder, NTT tables).
    pub ctx: Arc<Context>,
    /// Homomorphic evaluator for the indicator recovery (Eq. 6).
    pub ev: Evaluator,
    /// The client's encryptor/decryptor (holds the client secret key).
    pub enc: Encryptor,
    /// Fixed-point scale plan shared with the server.
    pub plan: ScalePlan,
    /// Compiled protocol spec both parties agree on.
    pub spec: ProtocolSpec,
    /// Indicator ciphertexts per step (received from the server offline).
    ids: Vec<(Vec<Ciphertext>, Vec<Ciphertext>)>,
    /// ChaCha20 key shared by the keygen stream and every query stream.
    seed_key: [u8; 32],
    /// Next unassigned query index (each query consumes one stream id).
    next_query: u64,
    /// The single query driven by the `&mut self` wrappers, if any.
    current: Option<ClientQuery>,
}

impl CheetahClient {
    /// Build a client: key generation draws from stream 0 of the expanded
    /// `seed`; queries later draw from streams `1, 2, …` (module docs).
    pub fn new(ctx: Arc<Context>, spec: ProtocolSpec, plan: ScalePlan, seed: u64) -> Self {
        let seed_key = ChaCha20Rng::key_from_u64(seed);
        let mut rng = ChaCha20Rng::new(&seed_key, KEYGEN_STREAM);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let n_steps = spec.steps.len();
        Self {
            ev: Evaluator::new(ctx.clone()),
            enc,
            plan,
            spec,
            ids: vec![(Vec::new(), Vec::new()); n_steps],
            seed_key,
            next_query: 0,
            current: None,
            ctx,
        }
    }

    /// Install the indicator ciphertexts for step `si` (offline phase).
    /// They arrive NTT-form ready for the client's `MultPlain`.
    pub fn install_indicators(&mut self, si: usize, id1: Vec<Ciphertext>, id2: Vec<Ciphertext>) {
        let mut id1 = id1;
        let mut id2 = id2;
        self.ev.to_ntt_batch(&mut id1);
        self.ev.to_ntt_batch(&mut id2);
        self.ids[si] = (id1, id2);
    }

    /// Reserve `count` consecutive query indices (batch dispatch): the
    /// caller hands index `base + i` to query `i` via
    /// [`CheetahClient::start_query`]. Looped single queries through
    /// [`CheetahClient::begin_query`] consume indices from the same
    /// counter, which is what makes loop and batch draw identically.
    pub fn reserve_queries(&mut self, count: u64) -> u64 {
        let base = self.next_query;
        self.next_query += count;
        base
    }

    /// Start query `query_index`: quantize the input into the client's
    /// initial share (the client holds the whole input; the server share
    /// starts at zero) and derive the query's own RNG stream.
    pub fn start_query(&self, input: &Tensor, query_index: u64) -> ClientQuery {
        let (c, h, w) = self.spec.input_shape;
        assert_eq!(input.shape(), (c, h, w), "input shape mismatch");
        let p = self.ctx.params.p;
        let share = input
            .data
            .iter()
            .map(|&v| {
                let q = self.plan.quant_x(v);
                if q < 0 {
                    p - ((-q) as u64)
                } else {
                    q as u64
                }
            })
            .collect();
        ClientQuery {
            share,
            last_y: Vec::new(),
            rng: ChaCha20Rng::new(&self.seed_key, QUERY_STREAM_BASE + query_index),
            online: Duration::ZERO,
        }
    }

    /// Begin a query on the internal single-query state (wrapper over
    /// [`CheetahClient::start_query`] with the next reserved index).
    pub fn begin_query(&mut self, input: &Tensor) {
        let qi = self.reserve_queries(1);
        self.current = Some(self.start_query(input, qi));
    }

    /// Single-query wrapper over [`CheetahClient::step_send_with`].
    pub fn step_send(&mut self, si: usize) -> Vec<Ciphertext> {
        let mut q = self.current.take().expect("begin_query before step_send");
        let out = self.step_send_with(si, &mut q);
        self.current = Some(q);
        out
    }

    /// Produce the client→server message for step `si`: the encrypted
    /// expanded share `[T(share_C)]_C`, encryption randomness drawn from
    /// the query's own stream.
    pub fn step_send_with(&self, si: usize, q: &mut ClientQuery) -> Vec<Ciphertext> {
        let t0 = Instant::now();
        let step = &self.spec.steps[si];
        let n = self.ctx.params.n;
        let expanded = step.linear.expand_u64(&q.share);
        let n_cts = step.linear.num_in_cts(n);
        let mut out = Vec::with_capacity(n_cts);
        for c in 0..n_cts {
            let lo = c * n;
            let hi = ((c + 1) * n).min(expanded.len());
            let pt = self.ctx.encoder.encode_unsigned(&expanded[lo..hi]);
            out.push(self.enc.encrypt(&pt, &mut q.rng));
        }
        q.online += t0.elapsed();
        out
    }

    /// Single-query wrapper over [`CheetahClient::step_receive_with`].
    pub fn step_receive(&mut self, si: usize, out_cts: &[Ciphertext]) -> Option<Vec<Ciphertext>> {
        let mut q = self.current.take().expect("begin_query before step_receive");
        let out = self.step_receive_with(si, out_cts, &mut q);
        self.current = Some(q);
        out
    }

    /// Consume the server's obscured products. Returns the recovery
    /// ciphertexts `[ReLU(Con+δ)·(scale) − s₁]_S` for intermediate steps,
    /// or `None` for the last step (the blinded logits land in `q`).
    pub fn step_receive_with(
        &self,
        si: usize,
        out_cts: &[Ciphertext],
        q: &mut ClientQuery,
    ) -> Option<Vec<Ciphertext>> {
        let t0 = Instant::now();
        let step = &self.spec.steps[si];
        if let LinearSpec::AvgPool { shape, size } = &step.linear {
            // Local step: no ciphertexts moved — the client sum-pools its
            // own share mod p (the server does the same; linearity makes
            // the reconstruction the pooled activation, and the mean
            // divisor is folded into the next linear step's weights).
            assert!(out_cts.is_empty(), "local steps receive no ciphertexts");
            q.share =
                super::server::pool_shares(&q.share, *shape, *size, self.ctx.params.p);
            q.online += t0.elapsed();
            return None;
        }
        let n = self.ctx.params.n;
        let len = step.linear.stream_len();
        let n_cts = step.linear.num_in_cts(n);
        let channels = step.linear.num_channels();
        let blocks = step.linear.blocks_per_channel();
        let block = step.linear.block_len();
        assert_eq!(out_cts.len(), channels * n_cts, "wrong response ct count");

        // Decrypt + block-sum (the obscure_dot hot loop): every ciphertext
        // decrypts independently — fan out over the (channel × ct) grid so
        // FC steps (one channel, many ciphertexts) parallelize too — then
        // block-sum per channel, concatenated in channel order. Both
        // regions are grained: a decrypt is heavy (floor 2), block sums are
        // light per channel (floor 8 — FC tails run them inline).
        let enc = &self.enc;
        let decs: Vec<Vec<i64>> = par::map_indexed_grained(channels * n_cts, 2, |k| {
            let c = k % n_cts;
            let vals = enc.decrypt_slots(&out_cts[k]);
            let hi = ((c + 1) * n).min(len) - c * n;
            let mut vals = vals;
            vals.truncate(hi);
            vals
        });
        let y_parts: Vec<Vec<i64>> = par::map_indexed_grained(channels, 8, |ch| {
            let mut stream: Vec<i64> = Vec::with_capacity(len);
            for c in 0..n_cts {
                stream.extend_from_slice(&decs[ch * n_cts + c]);
            }
            block_sums(&stream, block, blocks)
        });
        let mut y = Vec::with_capacity(channels * blocks);
        for part in y_parts {
            y.extend(part);
        }

        let last = si == self.spec.last_idx();
        if last {
            q.last_y = y;
            q.online += t0.elapsed();
            return None;
        }

        // Scrambled nonlinearity + polar-indicator recovery (relu_recover).
        let n_out = y.len();
        let mut y_req = vec![0i64; n_out];
        let mut relu_y = vec![0i64; n_out];
        for (i, &yi) in y.iter().enumerate() {
            let (a, b) = client_y_pair(yi, &self.plan);
            y_req[i] = a;
            relu_y[i] = b;
        }

        let (id1, id2) = &self.ids[si];
        let n_rec = step.linear.num_recovery_cts(n);
        assert_eq!(id1.len(), n_rec, "indicators not installed for step {si}");
        let p = self.ctx.params.p;
        // Draw the fresh shares s₁ first, strictly sequentially — the RNG
        // stream order must not depend on scheduling (same draw order as
        // the sequential code: ciphertext-major, slot-minor).
        let mut s1 = Vec::with_capacity(n_out);
        for _ in 0..n_out {
            s1.push(q.rng.gen_range(p));
        }
        // Eq. 6 per recovery ciphertext is then pure evaluator work
        // (Mult/Mult/Add/AddPlain) — independent across ciphertexts.
        let (ctx, ev) = (&self.ctx, &self.ev);
        let rec_out: Vec<Ciphertext> = par::map_indexed_grained(n_rec, 2, |c| {
            let lo = c * n;
            let hi = ((c + 1) * n).min(n_out);
            // Eq. 6: Add(Mult([ID1]_S, y), Mult([ID2]_S, ReLU(y))).
            let op_y = ctx.mult_operand(&y_req[lo..hi]);
            let op_r = ctx.mult_operand(&relu_y[lo..hi]);
            let mut rec = ev.mult_plain(&id1[c], &op_y);
            let rec2 = ev.mult_plain(&id2[c], &op_r);
            ev.add_assign(&mut rec, &rec2);
            // Subtract the client's fresh share s₁ (uniform mod p).
            let neg_s1: Vec<u64> = s1[lo..hi].iter().map(|&s| (p - s) % p).collect();
            let op_s = ctx.add_operand_unsigned(&neg_s1);
            ev.add_plain(&mut rec, &op_s);
            rec
        });

        // Residual steps: the client's saved share of the step *input*
        // joins its fresh share (the server mirrors this with its own
        // saved share), so the reconstruction gains `ReLU(linear(x)) + x`
        // with zero extra ciphertexts. Residuals are shape-preserving and
        // never combined with a fused pool (compile() guarantees both).
        if step.residual_add {
            assert_eq!(s1.len(), q.share.len(), "residual shapes must match");
            for (dst, &old) in s1.iter_mut().zip(q.share.iter()) {
                *dst = (*dst + old) % p;
            }
        }
        // The client's next-layer share is s₁ (sum-pooled if the network
        // pools here, mirroring the server).
        if let Some(size) = step.pool_after {
            s1 = super::server::pool_shares(&s1, step.out_shape, size, p);
        }
        q.share = s1;
        q.online += t0.elapsed();
        Some(rec_out)
    }

    /// Blinded logits of `q`, dequantized (product scale; the shared
    /// last-layer blind is the identity so these are the true logits up to
    /// quantization + δ).
    pub fn logits_of(&self, q: &ClientQuery) -> Vec<f64> {
        let s = self.plan.product();
        q.last_y.iter().map(|&v| s.dequantize(v)).collect()
    }

    /// Predicted class of `q`: last maximum of the blinded logits.
    pub fn argmax_of(&self, q: &ClientQuery) -> usize {
        q.last_y
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .expect("no logits yet")
    }

    /// Blinded logits of the internal single query (wrapper).
    pub fn logits(&self) -> Vec<f64> {
        self.logits_of(self.current.as_ref().expect("no query run yet"))
    }

    /// Predicted class of the internal single query (wrapper).
    pub fn argmax(&self) -> usize {
        self.argmax_of(self.current.as_ref().expect("no query run yet"))
    }

    /// The internal single query's current share (empty before any query).
    pub fn share(&self) -> &[u64] {
        self.current.as_ref().map(|q| q.share.as_slice()).unwrap_or(&[])
    }

    /// Direct share injection into the internal single-query state (tests /
    /// mid-network entry); starts a fresh query if none is active.
    pub fn set_share(&mut self, share: Vec<u64>) {
        if self.current.is_none() {
            let qi = self.reserve_queries(1);
            self.current = Some(ClientQuery {
                share: Vec::new(),
                last_y: Vec::new(),
                rng: ChaCha20Rng::new(&self.seed_key, QUERY_STREAM_BASE + qi),
                online: Duration::ZERO,
            });
        }
        self.current.as_mut().expect("just ensured").share = share;
    }

    /// Reset and return evaluator op counters.
    pub fn take_ops(&self) -> OpCounts {
        let c = self.ev.counts();
        self.ev.reset_counts();
        c
    }

    /// Take (and zero) the internal single query's attributed client time.
    pub fn reset_online(&mut self) -> Duration {
        self.current.as_mut().map(|q| std::mem::take(&mut q.online)).unwrap_or_default()
    }
}
