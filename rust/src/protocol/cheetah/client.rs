//! The CHEETAH client: encrypts its expanded activation share, finishes the
//! obscured linear transformation with plaintext block sums, computes the
//! scrambled nonlinearity, and recovers the server-encrypted exact ReLU via
//! the polar indicators (paper §3.1 step 3).
//!
//! The client's hot loops — the per-block sum of the decrypted obscured
//! products and the `ID₁∘y + ID₂∘ReLU(y)` recovery — are exactly what the
//! L1 Pallas kernels (`obscure_dot`, `relu_recover`) implement for the
//! accelerated plaintext path; golden vectors tie the two together.

use super::blinding::client_y_pair;
use super::packing::block_sums;
use super::spec::ProtocolSpec;
use crate::fixed::ScalePlan;
use crate::nn::Tensor;
use crate::par;
use crate::phe::{Ciphertext, Context, Encryptor, Evaluator, OpCounts};
use crate::util::rng::ChaCha20Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The client side of the CHEETAH protocol. Owns a shared `Arc<Context>`
/// (no lifetime parameter), so networked clients and engines can hold it
/// alongside the context without borrow gymnastics.
pub struct CheetahClient {
    pub ctx: Arc<Context>,
    pub ev: Evaluator,
    pub enc: Encryptor,
    pub plan: ScalePlan,
    pub spec: ProtocolSpec,
    /// Client's additive share (mod p) of the current activation.
    share: Vec<u64>,
    /// Indicator ciphertexts per step (received from the server offline).
    ids: Vec<(Vec<Ciphertext>, Vec<Ciphertext>)>,
    /// Blinded logits from the last layer (product scale).
    last_y: Vec<i64>,
    rng: ChaCha20Rng,
    pub online: Duration,
}

impl CheetahClient {
    pub fn new(ctx: Arc<Context>, spec: ProtocolSpec, plan: ScalePlan, seed: u64) -> Self {
        let mut rng = ChaCha20Rng::from_u64_seed(seed);
        let enc = Encryptor::new(ctx.clone(), &mut rng);
        let n_steps = spec.steps.len();
        Self {
            ev: Evaluator::new(ctx.clone()),
            enc,
            plan,
            spec,
            share: Vec::new(),
            ids: vec![(Vec::new(), Vec::new()); n_steps],
            last_y: Vec::new(),
            ctx,
            rng,
            online: Duration::ZERO,
        }
    }

    /// Install the indicator ciphertexts for step `si` (offline phase).
    /// They arrive NTT-form ready for the client's `MultPlain`.
    pub fn install_indicators(&mut self, si: usize, id1: Vec<Ciphertext>, id2: Vec<Ciphertext>) {
        let mut id1 = id1;
        let mut id2 = id2;
        self.ev.to_ntt_batch(&mut id1);
        self.ev.to_ntt_batch(&mut id2);
        self.ids[si] = (id1, id2);
    }

    /// Begin a query: quantize the input; the client's share IS the input
    /// (server share starts at zero).
    pub fn begin_query(&mut self, input: &Tensor) {
        let (c, h, w) = self.spec.input_shape;
        assert_eq!(input.shape(), (c, h, w), "input shape mismatch");
        let p = self.ctx.params.p;
        self.share = input
            .data
            .iter()
            .map(|&v| {
                let q = self.plan.quant_x(v);
                if q < 0 {
                    p - ((-q) as u64)
                } else {
                    q as u64
                }
            })
            .collect();
        self.last_y.clear();
    }

    /// Produce the client→server message for step `si`: the encrypted
    /// expanded share `[T(share_C)]_C`.
    pub fn step_send(&mut self, si: usize) -> Vec<Ciphertext> {
        let t0 = Instant::now();
        let step = &self.spec.steps[si];
        let n = self.ctx.params.n;
        let expanded = step.linear.expand_u64(&self.share);
        let n_cts = step.linear.num_in_cts(n);
        let mut out = Vec::with_capacity(n_cts);
        for c in 0..n_cts {
            let lo = c * n;
            let hi = ((c + 1) * n).min(expanded.len());
            let pt = self.ctx.encoder.encode_unsigned(&expanded[lo..hi]);
            out.push(self.enc.encrypt(&pt, &mut self.rng));
        }
        self.online += t0.elapsed();
        out
    }

    /// Consume the server's obscured products. Returns the recovery
    /// ciphertexts `[ReLU(Con+δ)·(scale) − s₁]_S` for intermediate steps,
    /// or `None` for the last step (the blinded logits are stored).
    pub fn step_receive(&mut self, si: usize, out_cts: &[Ciphertext]) -> Option<Vec<Ciphertext>> {
        let t0 = Instant::now();
        let step = &self.spec.steps[si];
        let n = self.ctx.params.n;
        let len = step.linear.stream_len();
        let n_cts = step.linear.num_in_cts(n);
        let channels = step.linear.num_channels();
        let blocks = step.linear.blocks_per_channel();
        let block = step.linear.block_len();
        assert_eq!(out_cts.len(), channels * n_cts, "wrong response ct count");

        // Decrypt + block-sum (the obscure_dot hot loop): every ciphertext
        // decrypts independently — fan out over the (channel × ct) grid so
        // FC steps (one channel, many ciphertexts) parallelize too — then
        // block-sum per channel, concatenated in channel order.
        let enc = &self.enc;
        let decs: Vec<Vec<i64>> = par::map_indexed(channels * n_cts, |k| {
            let c = k % n_cts;
            let vals = enc.decrypt_slots(&out_cts[k]);
            let hi = ((c + 1) * n).min(len) - c * n;
            let mut vals = vals;
            vals.truncate(hi);
            vals
        });
        let y_parts: Vec<Vec<i64>> = par::map_indexed(channels, |ch| {
            let mut stream: Vec<i64> = Vec::with_capacity(len);
            for c in 0..n_cts {
                stream.extend_from_slice(&decs[ch * n_cts + c]);
            }
            block_sums(&stream, block, blocks)
        });
        let mut y = Vec::with_capacity(channels * blocks);
        for part in y_parts {
            y.extend(part);
        }

        let last = si == self.spec.last_idx();
        if last {
            self.last_y = y;
            self.online += t0.elapsed();
            return None;
        }

        // Scrambled nonlinearity + polar-indicator recovery (relu_recover).
        let n_out = y.len();
        let mut y_req = vec![0i64; n_out];
        let mut relu_y = vec![0i64; n_out];
        for (i, &yi) in y.iter().enumerate() {
            let (a, b) = client_y_pair(yi, &self.plan);
            y_req[i] = a;
            relu_y[i] = b;
        }

        let (id1, id2) = &self.ids[si];
        let n_rec = step.linear.num_recovery_cts(n);
        assert_eq!(id1.len(), n_rec, "indicators not installed for step {si}");
        let p = self.ctx.params.p;
        // Draw the fresh shares s₁ first, strictly sequentially — the RNG
        // stream order must not depend on scheduling (same draw order as
        // the sequential code: ciphertext-major, slot-minor).
        let mut s1 = Vec::with_capacity(n_out);
        for _ in 0..n_out {
            s1.push(self.rng.gen_range(p));
        }
        // Eq. 6 per recovery ciphertext is then pure evaluator work
        // (Mult/Mult/Add/AddPlain) — independent across ciphertexts.
        let (ctx, ev) = (&self.ctx, &self.ev);
        let rec_out: Vec<Ciphertext> = par::map_indexed(n_rec, |c| {
            let lo = c * n;
            let hi = ((c + 1) * n).min(n_out);
            // Eq. 6: Add(Mult([ID1]_S, y), Mult([ID2]_S, ReLU(y))).
            let op_y = ctx.mult_operand(&y_req[lo..hi]);
            let op_r = ctx.mult_operand(&relu_y[lo..hi]);
            let mut rec = ev.mult_plain(&id1[c], &op_y);
            let rec2 = ev.mult_plain(&id2[c], &op_r);
            ev.add_assign(&mut rec, &rec2);
            // Subtract the client's fresh share s₁ (uniform mod p).
            let neg_s1: Vec<u64> = s1[lo..hi].iter().map(|&s| (p - s) % p).collect();
            let op_s = ctx.add_operand_unsigned(&neg_s1);
            ev.add_plain(&mut rec, &op_s);
            rec
        });

        // The client's next-layer share is s₁ (sum-pooled if the network
        // pools here, mirroring the server).
        if let Some(size) = step.pool_after {
            s1 = super::server::pool_shares(&s1, step.out_shape, size, p);
        }
        self.share = s1;
        self.online += t0.elapsed();
        Some(rec_out)
    }

    /// Blinded logits from the last layer, dequantized (product scale; the
    /// shared last-layer blind is the identity so these are the true logits
    /// up to quantization + δ).
    pub fn logits(&self) -> Vec<f64> {
        let s = self.plan.product();
        self.last_y.iter().map(|&v| s.dequantize(v)).collect()
    }

    pub fn argmax(&self) -> usize {
        self.last_y
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .expect("no logits yet")
    }

    pub fn share(&self) -> &[u64] {
        &self.share
    }

    pub fn set_share(&mut self, share: Vec<u64>) {
        self.share = share;
    }

    pub fn take_ops(&self) -> OpCounts {
        let c = self.ev.counts();
        self.ev.reset_counts();
        c
    }

    pub fn reset_online(&mut self) -> Duration {
        std::mem::take(&mut self.online)
    }
}
