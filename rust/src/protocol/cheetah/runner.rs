//! End-to-end CHEETAH inference: drives client and server through every
//! step, meters exact serialized traffic through the link model, and
//! produces the per-layer report behind the paper's Table 7 / Fig. 8.

use super::client::CheetahClient;
use super::server::CheetahServer;
use super::spec::{ProtocolSpec, SpecError};
use crate::fixed::ScalePlan;
use crate::nn::{Network, Tensor};
use crate::phe::serial::ciphertext_bytes;
use crate::phe::{Context, OpCounts};
use crate::protocol::transport::{Dir, LinkModel, MeteredChannel};
use std::sync::Arc;
use std::time::Duration;

/// Per-step accounting (one fused linear[+ReLU][+pool] step).
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    pub name: String,
    pub client_time: Duration,
    pub server_online: Duration,
    pub server_offline: Duration,
    pub c2s_bytes: u64,
    pub s2c_bytes: u64,
    pub server_ops: OpCounts,
    pub client_ops: OpCounts,
}

/// Whole-query report.
#[derive(Clone, Debug, Default)]
pub struct InferenceReport {
    pub argmax: usize,
    pub logits: Vec<f64>,
    pub steps: Vec<StepReport>,
    /// Offline bytes: indicator ciphertexts shipped ahead of the query.
    pub offline_bytes: u64,
    pub offline_time: Duration,
    /// Modeled wire time for the online traffic.
    pub wire_time: Duration,
}

impl InferenceReport {
    pub fn online_compute(&self) -> Duration {
        self.steps.iter().map(|s| s.client_time + s.server_online).sum()
    }
    pub fn online_total(&self) -> Duration {
        self.online_compute() + self.wire_time
    }
    pub fn online_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.c2s_bytes + s.s2c_bytes).sum()
    }
    pub fn total_ops(&self) -> OpCounts {
        self.steps
            .iter()
            .fold(OpCounts::default(), |acc, s| acc.plus(&s.server_ops).plus(&s.client_ops))
    }
}

/// An in-process CHEETAH deployment: both parties plus a metered link.
pub struct CheetahRunner {
    pub server: CheetahServer,
    pub client: CheetahClient,
    pub channel: MeteredChannel,
}

impl CheetahRunner {
    pub fn new(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
    ) -> Result<Self, SpecError> {
        Self::with_link(ctx, net, plan, epsilon, seed, LinkModel::gigabit_lan())
    }

    /// Like [`CheetahRunner::new`] with an explicit link cost model. A
    /// network the protocol cannot express is a typed [`SpecError`].
    pub fn with_link(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
        link: LinkModel,
    ) -> Result<Self, SpecError> {
        let server = CheetahServer::new(ctx.clone(), net, plan, epsilon, seed)?;
        let client = CheetahClient::new(ctx, server.spec.clone(), plan, seed.wrapping_add(1));
        Ok(Self { server, client, channel: MeteredChannel::new(link) })
    }

    pub fn spec(&self) -> &ProtocolSpec {
        &self.server.spec
    }

    /// Ship the offline material (indicator ciphertexts) and return its
    /// size — the paper's "offline communication".
    pub fn run_offline(&mut self) -> u64 {
        let params = &self.server.ctx.params;
        let mut bytes = 0u64;
        for si in 0..self.spec().steps.len() {
            let (id1, id2) = self.server.indicator_cts(si);
            bytes += ((id1.len() + id2.len()) * ciphertext_bytes(params, true)) as u64;
            self.client.install_indicators(si, id1.to_vec(), id2.to_vec());
        }
        bytes
    }

    /// Run one private inference end to end.
    pub fn infer(&mut self, input: &Tensor) -> InferenceReport {
        let params = &self.server.ctx.params;
        let fresh = ciphertext_bytes(params, true) as u64;
        let eval = ciphertext_bytes(params, false) as u64;

        let mut report = InferenceReport {
            offline_time: self.server.timers.offline,
            ..Default::default()
        };
        self.server.reset_timers();
        self.client.reset_online();
        self.server.take_ops();
        self.client.take_ops();
        self.channel.reset();

        self.client.begin_query(input);
        self.server.begin_query();

        let n_steps = self.spec().steps.len();
        for si in 0..n_steps {
            let mut step_rep = StepReport {
                name: format!(
                    "step{si}:{}",
                    match &self.spec().steps[si].linear {
                        super::spec::LinearSpec::Conv(_) => "conv",
                        super::spec::LinearSpec::Fc(_) => "fc",
                    }
                ),
                ..Default::default()
            };

            // C → S: encrypted expanded share.
            let in_cts = self.client.step_send(si);
            for _ in &in_cts {
                self.channel.send(Dir::ClientToServer, fresh);
                step_rep.c2s_bytes += fresh;
            }

            // S: obscure linear computation.
            let out_cts = self.server.step_linear(si, &in_cts);
            for _ in &out_cts {
                self.channel.send(Dir::ServerToClient, eval);
                step_rep.s2c_bytes += eval;
            }

            // C: block sums (+ recovery for intermediate steps).
            if let Some(rec) = self.client.step_receive(si, &out_cts) {
                for _ in &rec {
                    self.channel.send(Dir::ClientToServer, eval);
                    step_rep.c2s_bytes += eval;
                }
                self.server.finish_nonlinear(si, &rec);
            }

            let t = self.server.reset_timers();
            step_rep.server_online = t.online;
            step_rep.server_offline = t.offline;
            step_rep.client_time = self.client.reset_online();
            step_rep.server_ops = self.server.take_ops();
            step_rep.client_ops = self.client.take_ops();
            report.steps.push(step_rep);
        }

        report.argmax = self.client.argmax();
        report.logits = self.client.logits();
        report.wire_time = self.channel.wire_time;
        report
    }
}
