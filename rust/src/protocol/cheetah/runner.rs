//! End-to-end CHEETAH inference: drives client and server through every
//! step, meters exact serialized traffic through the link model, and
//! produces the per-layer report behind the paper's Table 7 / Fig. 8.
//!
//! Two driving modes share one prepared deployment:
//!
//! * [`CheetahRunner::infer`] — one query, exact per-step attribution
//!   (timing, ops, traffic),
//! * [`CheetahRunner::infer_batch`] — independent queries fanned across
//!   the [`crate::par`] pool, bit-identical logits to the looped
//!   sequential path (per-query RNG stream isolation; see
//!   [`super::client`] module docs).

use super::client::CheetahClient;
use super::server::CheetahServer;
use super::spec::{ProtocolSpec, SpecError};
use crate::fixed::ScalePlan;
use crate::nn::{Network, Tensor};
use crate::par;
use crate::phe::serial::ciphertext_bytes;
use crate::phe::{Context, OpCounts};
use crate::protocol::transport::{Dir, LinkModel, MeteredChannel};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-step accounting (one fused linear[+ReLU][+pool] step).
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// Step label (`step0:conv`, `step1:fc`, …).
    pub name: String,
    /// Client compute attributed to this step.
    pub client_time: Duration,
    /// Server query-dependent compute attributed to this step.
    pub server_online: Duration,
    /// Server query-independent compute observed during this step.
    pub server_offline: Duration,
    /// Client→server bytes (exact serialized sizes).
    pub c2s_bytes: u64,
    /// Server→client bytes.
    pub s2c_bytes: u64,
    /// Server HE op counts for this step.
    pub server_ops: OpCounts,
    /// Client HE op counts for this step.
    pub client_ops: OpCounts,
}

/// Whole-query report.
#[derive(Clone, Debug, Default)]
pub struct InferenceReport {
    /// Predicted class (last maximum of the logits).
    pub argmax: usize,
    /// Dequantized logits.
    pub logits: Vec<f64>,
    /// Per fused-step accounting (a single synthetic step in batch mode).
    pub steps: Vec<StepReport>,
    /// Offline bytes: indicator ciphertexts shipped ahead of the query.
    pub offline_bytes: u64,
    /// Offline preparation time observed so far.
    pub offline_time: Duration,
    /// Modeled wire time for the online traffic.
    pub wire_time: Duration,
}

impl InferenceReport {
    /// Total online compute across both parties (no wire time).
    pub fn online_compute(&self) -> Duration {
        self.steps.iter().map(|s| s.client_time + s.server_online).sum()
    }
    /// Online compute plus the modeled wire time.
    pub fn online_total(&self) -> Duration {
        self.online_compute() + self.wire_time
    }
    /// Total online bytes, both directions.
    pub fn online_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.c2s_bytes + s.s2c_bytes).sum()
    }
    /// Aggregate HE op counts across all steps and both parties.
    pub fn total_ops(&self) -> OpCounts {
        self.steps
            .iter()
            .fold(OpCounts::default(), |acc, s| acc.plus(&s.server_ops).plus(&s.client_ops))
    }
}

/// An in-process CHEETAH deployment: both parties plus a metered link.
pub struct CheetahRunner {
    /// The server party (model, blinding material, indicators).
    pub server: CheetahServer,
    /// The client party (keys, share chain).
    pub client: CheetahClient,
    /// The metered in-process link between them.
    pub channel: MeteredChannel,
}

impl CheetahRunner {
    /// Build a deployment over the default gigabit-LAN link model.
    /// Seed convention: server blinding uses `seed`, the client `seed + 1`
    /// (see the [`super`] module docs).
    pub fn new(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
    ) -> Result<Self, SpecError> {
        Self::with_link(ctx, net, plan, epsilon, seed, LinkModel::gigabit_lan())
    }

    /// Like [`CheetahRunner::new`] with an explicit link cost model. A
    /// network the protocol cannot express is a typed [`SpecError`].
    pub fn with_link(
        ctx: Arc<Context>,
        net: Network,
        plan: ScalePlan,
        epsilon: f64,
        seed: u64,
        link: LinkModel,
    ) -> Result<Self, SpecError> {
        let server = CheetahServer::new(ctx.clone(), net, plan, epsilon, seed)?;
        let client = CheetahClient::new(ctx, server.spec.clone(), plan, seed.wrapping_add(1));
        Ok(Self { server, client, channel: MeteredChannel::new(link) })
    }

    /// The compiled protocol spec both parties share.
    pub fn spec(&self) -> &ProtocolSpec {
        &self.server.spec
    }

    /// Ship the offline material (indicator ciphertexts) and return its
    /// size — the paper's "offline communication".
    pub fn run_offline(&mut self) -> u64 {
        let params = &self.server.ctx.params;
        let mut bytes = 0u64;
        for si in 0..self.spec().steps.len() {
            let (id1, id2) = self.server.indicator_cts(si);
            bytes += ((id1.len() + id2.len()) * ciphertext_bytes(params, true)) as u64;
            self.client.install_indicators(si, id1.to_vec(), id2.to_vec());
        }
        bytes
    }

    /// Run one private inference end to end.
    pub fn infer(&mut self, input: &Tensor) -> InferenceReport {
        let params = &self.server.ctx.params;
        let fresh = ciphertext_bytes(params, true) as u64;
        let eval = ciphertext_bytes(params, false) as u64;

        let mut report = InferenceReport {
            offline_time: self.server.timers().offline,
            ..Default::default()
        };
        self.server.reset_timers();
        self.client.reset_online();
        self.server.take_ops();
        self.client.take_ops();
        self.channel.reset();

        self.client.begin_query(input);
        self.server.begin_query();

        let n_steps = self.spec().steps.len();
        for si in 0..n_steps {
            let mut step_rep = StepReport {
                name: format!(
                    "step{si}:{}",
                    match &self.spec().steps[si].linear {
                        super::spec::LinearSpec::Conv(_) => "conv",
                        super::spec::LinearSpec::Fc(_) => "fc",
                        super::spec::LinearSpec::AvgPool { .. } => "avgpool",
                    }
                ),
                ..Default::default()
            };

            // C → S: encrypted expanded share.
            let in_cts = self.client.step_send(si);
            for _ in &in_cts {
                self.channel.send(Dir::ClientToServer, fresh);
                step_rep.c2s_bytes += fresh;
            }

            // S: obscure linear computation.
            let out_cts = self.server.step_linear(si, &in_cts);
            for _ in &out_cts {
                self.channel.send(Dir::ServerToClient, eval);
                step_rep.s2c_bytes += eval;
            }

            // C: block sums (+ recovery for intermediate steps). Local
            // steps (standalone AvgPool) return no recovery material —
            // each party transforms its own share instead.
            if let Some(rec) = self.client.step_receive(si, &out_cts) {
                for _ in &rec {
                    self.channel.send(Dir::ClientToServer, eval);
                    step_rep.c2s_bytes += eval;
                }
                self.server.finish_nonlinear(si, &rec);
            } else if self.spec().steps[si].is_local() {
                self.server.finish_local(si);
            }

            let t = self.server.reset_timers();
            step_rep.server_online = t.online;
            step_rep.server_offline = t.offline;
            step_rep.client_time = self.client.reset_online();
            step_rep.server_ops = self.server.take_ops();
            step_rep.client_ops = self.client.take_ops();
            report.steps.push(step_rep);
        }

        report.argmax = self.client.argmax();
        report.logits = self.client.logits();
        report.wire_time = self.channel.wire_time;
        report
    }

    /// Run a batch of independent queries, fanned across the
    /// [`crate::par`] pool (one fork-join region; each chunk drives one
    /// full query through the stateless client/server cores).
    ///
    /// Every query gets its own state — client share chain + RNG stream
    /// derived from `(client seed, query index)`, server share — against
    /// the *same* prepared deployment (same blinding material, same keys),
    /// so the logits are **bit-identical** to looping
    /// [`CheetahRunner::infer`] over the same inputs, at every thread
    /// count and batch size.
    ///
    /// Per-query reports carry wall time (one synthetic step whose
    /// `client_time` is the query's end-to-end compute), exact per-query
    /// traffic, and the modeled per-query wire time. Evaluator op counts
    /// and per-step timing are *not* attributed per query (the counters
    /// are shared across concurrent queries) — use [`CheetahRunner::infer`]
    /// for those.
    pub fn infer_batch(&mut self, inputs: &[Tensor]) -> Vec<InferenceReport> {
        if inputs.is_empty() {
            return Vec::new();
        }
        let base = self.client.reserve_queries(inputs.len() as u64);
        let params = &self.server.ctx.params;
        let fresh = ciphertext_bytes(params, true) as u64;
        let eval = ciphertext_bytes(params, false) as u64;
        let link = self.channel.link;
        // Take (and zero) the accumulated offline time once for the whole
        // batch — mirroring the looped path, where the first `infer`
        // reports it and later ones report ~0. Summing a batch's reports
        // therefore counts the offline cost once, not N times, and a later
        // single `infer` doesn't re-report it. Offline accrued *during*
        // the batch (tiled operand rebuilds on over-budget steps) is
        // collected after the region and folded into query 0's report too.
        let offline_time = self.server.reset_timers().offline;
        let server = &self.server;
        let client = &self.client;
        let n_steps = server.spec.steps.len();
        let mut reports = par::map_indexed(inputs.len(), |i| {
            let t0 = Instant::now();
            let mut q = client.start_query(&inputs[i], base + i as u64);
            let mut s_share = server.fresh_share();
            let (mut c2s, mut s2c) = (0u64, 0u64);
            let mut wire = Duration::ZERO;
            for si in 0..n_steps {
                let in_cts = client.step_send_with(si, &mut q);
                for _ in &in_cts {
                    c2s += fresh;
                    wire += link.transfer_time(fresh);
                }
                let out_cts = server.step_linear_with(si, &in_cts, &s_share);
                for _ in &out_cts {
                    s2c += eval;
                    wire += link.transfer_time(eval);
                }
                if let Some(rec) = client.step_receive_with(si, &out_cts, &mut q) {
                    for _ in &rec {
                        c2s += eval;
                        wire += link.transfer_time(eval);
                    }
                    s_share = server.advance_share(si, &rec, &s_share);
                } else if server.spec.steps[si].is_local() {
                    s_share = server.local_share(si, &s_share);
                }
            }
            InferenceReport {
                argmax: client.argmax_of(&q),
                logits: client.logits_of(&q),
                steps: vec![StepReport {
                    name: "batch-query".into(),
                    client_time: t0.elapsed(),
                    c2s_bytes: c2s,
                    s2c_bytes: s2c,
                    ..Default::default()
                }],
                offline_bytes: 0,
                offline_time: Duration::ZERO,
                wire_time: wire,
            }
        });
        let in_batch = self.server.reset_timers().offline;
        if let Some(first) = reports.first_mut() {
            first.offline_time = offline_time + in_batch;
        }
        reports
    }
}
