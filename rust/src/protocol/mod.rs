//! Two-party private-inference protocols.
//!
//! * [`cheetah`] — the paper's contribution: permutation-free obscure linear
//!   computation + PHE-based secret-share nonlinear recovery.
//! * [`gazelle`] — the state-of-the-art baseline the paper compares to:
//!   rotation-based packed linear algebra + garbled-circuit ReLU.
//! * [`transport`] — message framing, byte metering and a link cost model.

pub mod cheetah;
#[allow(missing_docs)] // legacy module: rustdoc coverage tracked in README
pub mod gazelle;
pub mod transport;
