//! Two-party private-inference protocols.
//!
//! * [`cheetah`] — the paper's contribution: permutation-free obscure linear
//!   computation + PHE-based secret-share nonlinear recovery.
//! * [`gazelle`] — the state-of-the-art baseline the paper compares to:
//!   rotation-based packed linear algebra + garbled-circuit ReLU.
//! * [`gala`] — the baseline's greedy-packing successor (GALA, NDSS'21):
//!   block-combined FC and kernel-grouped conv that cut the dominant
//!   rotation count, driven through the same GAZELLE runner.
//! * [`transport`] — message framing, byte metering and a link cost model.

pub mod cheetah;
pub mod gala;
pub mod gazelle;
pub mod transport;
