//! Dependency-free structured concurrency for the crate's hot paths.
//!
//! CHEETAH's speed story is built on restructuring linear layers into
//! *embarrassingly parallel* per-channel ciphertext streams, so the runtime
//! here is deliberately minimal: a lazily-started global pool of worker
//! threads plus three fork-join primitives ([`join`], [`for_each_chunked`] /
//! [`for_each_chunk_mut`], [`map_indexed`] / [`map_collect`] /
//! [`map_indexed_grained`]). There is no
//! work stealing and no task graph — every parallel region statically
//! partitions its work by index, writes results into pre-sized slots, and
//! blocks the caller until the whole region is done.
//!
//! **Determinism by construction.** Which thread executes a chunk races;
//! *what* each chunk computes and *where* it writes never does. All the
//! arithmetic the crate fans out is exact integer/modular math with no
//! cross-chunk accumulation, so the output of any parallel region is
//! bit-identical to the sequential loop it replaced, for every thread
//! count (the integration tests sweep 1/2/8 and assert exactly this).
//!
//! **Sequential fallback.** With an effective thread count of 1 (the
//! `--threads 1` CLI knob, `CHEETAH_THREADS=1`, a [`with_threads`]`(1, …)`
//! scope, or a single-core host) every primitive degenerates to the plain
//! `for` loop — the pool is never started and no worker thread is ever
//! spawned.
//!
//! **Thread-count resolution.** [`threads()`] answers, in priority order:
//! the innermost [`with_threads`] scope on the calling thread (how
//! per-engine and per-server overrides stay isolated from each other),
//! then the [`set_threads`] process-global, then the default
//! (`CHEETAH_THREADS` env var, else `available_parallelism()`).
//!
//! **Nested regions.** A region's caller first claims and executes unclaimed
//! chunks itself, then waits only on chunks other threads have already
//! claimed. A chunk may itself open a nested region (the same rule applies),
//! so waiting always points at strictly younger regions — the blocking graph
//! is acyclic and nested [`join`]s cannot deadlock even when every worker is
//! busy.
//!
//! RNG-consuming protocol material (blinding draws, fresh shares, key/error
//! sampling) deliberately stays **outside** this module: consuming a shared
//! RNG from racing threads would make the draw order scheduling-dependent.
//! Callers either keep those loops sequential or derive an independent,
//! deterministically-seeded stream per chunk (as the CHEETAH server does for
//! its per-channel noise streams).

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// Explicit thread-count override (0 = unset, fall back to the default).
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);
/// Resolved default: `CHEETAH_THREADS` env var, else `available_parallelism`.
static DEFAULT: OnceLock<usize> = OnceLock::new();
/// Resolved `CHEETAH_PAR_GRAIN` floor (see [`grain_floor`]).
static GRAIN: OnceLock<usize> = OnceLock::new();

thread_local! {
    /// Per-thread scoped override (0 = none); see [`with_threads`].
    static SCOPED: Cell<usize> = const { Cell::new(0) };
}

fn default_threads() -> usize {
    *DEFAULT.get_or_init(|| {
        if let Ok(v) = std::env::var("CHEETAH_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Set the **process-global** thread count. `0` restores the default
/// (`CHEETAH_THREADS` env var, else `available_parallelism()`); `1` forces
/// the exact sequential code path everywhere. Prefer [`with_threads`] (or
/// `EngineBuilder::threads`, which uses it) when the override should apply
/// to one engine or server rather than the whole process.
pub fn set_threads(n: usize) {
    CONFIGURED.store(n, Ordering::Relaxed);
}

/// The effective thread count parallel regions opened *on this thread*
/// will target: the innermost [`with_threads`] scope if one is active,
/// else the [`set_threads`] global, else the default.
pub fn threads() -> usize {
    let scoped = SCOPED.with(|s| s.get());
    if scoped > 0 {
        return scoped;
    }
    match CONFIGURED.load(Ordering::Relaxed) {
        0 => default_threads(),
        n => n,
    }
}

/// Run `f` with the effective thread count pinned to `n` — **scoped to the
/// calling thread**, restored (panic-safe) when `f` returns. `n = 0` is a
/// no-op scope (the global setting stays in effect); `n = 1` makes every
/// parallel region opened inside `f` on this thread run the exact
/// sequential code path.
///
/// This is how per-engine/per-server thread counts work without the
/// builders racing each other over the [`set_threads`] global: an engine
/// built with `EngineBuilder::threads(n)` wraps its `prepare`/`infer`
/// calls in `with_threads(n, …)`, and a `SecureServer` pins its worker
/// and pool-builder threads the same way — so constructing a builder can
/// never resize a live server's parallelism.
///
/// Scope caveat: the override travels with *this* thread only. A region
/// opened inside `f` fans its chunks out to pool workers, and a chunk that
/// itself opens a nested region does so under the **worker's** setting
/// (scoped if the worker is inside its own `with_threads`, else the
/// global). Results are unaffected either way — parallel output is
/// bit-exact at every thread count — only the fan-out width is.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    if n == 0 {
        // A no-op scope must not cancel an enclosing `with_threads` pin.
        return f();
    }
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            SCOPED.with(|s| s.set(self.0));
        }
    }
    let prev = SCOPED.with(|s| {
        let p = s.get();
        s.set(n);
        p
    });
    let _restore = Restore(prev);
    f()
}

// ---------------------------------------------------------------------------
// Region: one fork-join parallel section
// ---------------------------------------------------------------------------

/// A parallel region: `n` chunks claimed by index from `next`, executed via
/// the lifetime-erased chunk function `f`.
///
/// Safety contract for the erased lifetime: `f` is only ever invoked for a
/// claimed index `i < n`, and the submitting caller does not return from
/// [`run_chunks`] until `finished == n` — i.e. until every claimed chunk has
/// completed. The header itself lives in an `Arc`, so a late worker that
/// pops an already-exhausted region only touches the (still-alive) atomics
/// and never calls `f`.
struct Region {
    f: &'static (dyn Fn(usize) + Sync),
    n: usize,
    next: AtomicUsize,
    finished: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

impl Region {
    /// Claim and execute chunks until none are left unclaimed.
    fn drain(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                return;
            }
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| (self.f)(i))) {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            // AcqRel: publishes this chunk's writes to whoever observes the
            // final count (the RMW chain forms one release sequence).
            if self.finished.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
                let mut done = self.done.lock().unwrap();
                *done = true;
                self.done_cv.notify_all();
            }
        }
    }

    /// Block until every chunk (including ones claimed by workers) is done.
    fn wait(&self) {
        if self.finished.load(Ordering::Acquire) >= self.n {
            return;
        }
        let mut done = self.done.lock().unwrap();
        while !*done {
            // The timeout is belt-and-braces against a lost wakeup; the
            // predicate re-check is what actually terminates the loop.
            let (g, _) = self.done_cv.wait_timeout(done, Duration::from_millis(1)).unwrap();
            done = g;
            if self.finished.load(Ordering::Acquire) >= self.n {
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Global pool
// ---------------------------------------------------------------------------

struct Pool {
    queue: Mutex<VecDeque<Arc<Region>>>,
    work_cv: Condvar,
    spawned: Mutex<usize>,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        queue: Mutex::new(VecDeque::new()),
        work_cv: Condvar::new(),
        spawned: Mutex::new(0),
    })
}

impl Pool {
    /// Hand `helpers` claim tickets for `region` to the workers (spawning
    /// workers lazily up to the requested count).
    fn submit(&'static self, region: &Arc<Region>, helpers: usize) {
        self.ensure_workers(helpers);
        {
            let mut q = self.queue.lock().unwrap();
            for _ in 0..helpers {
                q.push_back(region.clone());
            }
            // Queue depth is only meaningful under the lock: this is the
            // instantaneous number of un-popped claim tickets.
            crate::obs::gauge_set("par.queue_depth", q.len() as i64);
        }
        if helpers == 1 {
            self.work_cv.notify_one();
        } else {
            self.work_cv.notify_all();
        }
    }

    fn ensure_workers(&'static self, want: usize) {
        let mut spawned = self.spawned.lock().unwrap();
        while *spawned < want {
            let idx = *spawned;
            std::thread::Builder::new()
                .name(format!("cheetah-par-{idx}"))
                .spawn(move || self.worker_loop())
                .expect("spawn par worker");
            *spawned += 1;
        }
    }

    fn worker_loop(&'static self) {
        loop {
            let region = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if let Some(r) = q.pop_front() {
                        break r;
                    }
                    q = self.work_cv.wait(q).unwrap();
                }
            };
            region.drain();
        }
    }
}

/// Execute `f(0), f(1), …, f(n-1)` across the caller plus up to
/// `threads()-1` pool workers; returns once all `n` chunks completed. The
/// caller participates (and drains every unclaimed chunk itself), so a
/// region always makes progress even when every worker is busy. Panics in
/// any chunk are re-raised on the caller after the region completes.
fn run_chunks(n: usize, f: &(dyn Fn(usize) + Sync)) {
    if n == 0 {
        return;
    }
    let t = threads();
    if t <= 1 || n == 1 {
        crate::obs::inc("par.regions.inline");
        for i in 0..n {
            f(i);
        }
        return;
    }
    crate::obs::inc("par.regions.forked");
    crate::obs::add("par.chunks", n as u64);
    // Lifetime erasure: see the Region safety contract above — `f` is only
    // called for claimed chunks, all of which complete before this function
    // returns, so the borrow outlives every call.
    #[allow(clippy::useless_transmute)]
    let f_erased: &'static (dyn Fn(usize) + Sync) =
        unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f) };
    let region = Arc::new(Region {
        f: f_erased,
        n,
        next: AtomicUsize::new(0),
        finished: AtomicUsize::new(0),
        panic: Mutex::new(None),
        done: Mutex::new(false),
        done_cv: Condvar::new(),
    });
    let helpers = (t - 1).min(n - 1);
    pool().submit(&region, helpers);
    region.drain();
    region.wait();
    if let Some(p) = region.panic.lock().unwrap().take() {
        resume_unwind(p);
    }
}

// ---------------------------------------------------------------------------
// Public primitives
// ---------------------------------------------------------------------------

/// Run two closures, potentially in parallel, and return both results.
/// With `threads() == 1` this is exactly `(a(), b())`.
pub fn join<RA, RB, A, B>(a: A, b: B) -> (RA, RB)
where
    A: FnOnce() -> RA + Send,
    B: FnOnce() -> RB + Send,
    RA: Send,
    RB: Send,
{
    if threads() <= 1 {
        return (a(), b());
    }
    let a_cell = Mutex::new(Some(a));
    let b_cell = Mutex::new(Some(b));
    let ra = Mutex::new(None);
    let rb = Mutex::new(None);
    run_chunks(2, &|i| {
        if i == 0 {
            let f = a_cell.lock().unwrap().take().expect("join chunk 0 claimed twice");
            *ra.lock().unwrap() = Some(f());
        } else {
            let f = b_cell.lock().unwrap().take().expect("join chunk 1 claimed twice");
            *rb.lock().unwrap() = Some(f());
        }
    });
    (
        ra.into_inner().unwrap().expect("join arm a did not run"),
        rb.into_inner().unwrap().expect("join arm b did not run"),
    )
}

/// Split `0..len` into contiguous index ranges of at least `min_grain`
/// elements and run `f(lo, hi)` on each, in parallel. Ranges are disjoint
/// and cover `0..len` exactly once; `f` must only touch state owned by its
/// range.
pub fn for_each_chunked<F: Fn(usize, usize) + Sync>(len: usize, min_grain: usize, f: F) {
    if len == 0 {
        return;
    }
    let grain = min_grain.max(1);
    // Over-partition by 4x the thread count for load balance, but never
    // below the grain size.
    let n_chunks = len.div_ceil(grain).min(threads().saturating_mul(4)).max(1);
    run_chunks(n_chunks, &|c| {
        let lo = c * len / n_chunks;
        let hi = (c + 1) * len / n_chunks;
        if lo < hi {
            f(lo, hi);
        }
    });
}

/// Covariant raw-pointer handle used to hand disjoint `&mut` sub-slices of
/// one allocation to different chunks.
struct SlicePtr<T>(*mut T);
// Safety: each chunk derives a reference only to its own disjoint region.
unsafe impl<T: Send> Sync for SlicePtr<T> {}

/// Split `data` into consecutive chunks of `chunk_len` elements (the last
/// may be shorter) and run `f(chunk_index, &mut chunk)` on each in
/// parallel. This is the mutable-output workhorse: chunk `i` owns
/// `data[i*chunk_len .. (i+1)*chunk_len]` exclusively.
pub fn for_each_chunk_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let len = data.len();
    if len == 0 {
        return;
    }
    let base = SlicePtr(data.as_mut_ptr());
    let n_chunks = len.div_ceil(chunk_len);
    run_chunks(n_chunks, &|i| {
        let lo = i * chunk_len;
        let hi = ((i + 1) * chunk_len).min(len);
        // Safety: chunk indices are claimed exactly once and the ranges
        // [lo, hi) are pairwise disjoint, so this &mut aliases nothing.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
        f(i, chunk);
    });
}

/// Run `f(i, &mut data[i])` for every element, in parallel.
pub fn for_each_mut<T, F>(data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    for_each_chunk_mut(data, 1, |i, chunk| f(i, &mut chunk[0]));
}

/// Compute `[f(0), f(1), …, f(n-1)]` in parallel, each result written into
/// its pre-sized slot (so the output order is exactly the index order,
/// independent of scheduling).
pub fn map_indexed<R: Send, F: Fn(usize) -> R + Sync>(n: usize, f: F) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for_each_mut(&mut out, |i, slot| *slot = Some(f(i)));
    out.into_iter().map(|o| o.expect("parallel map slot left unfilled")).collect()
}

/// The global minimum region size for grained dispatch: regions opened
/// through [`map_indexed_grained`] with fewer units than this run on the
/// caller's thread with no fork-join handshake at all. Resolved once from
/// the `CHEETAH_PAR_GRAIN` env var; defaults to 2 (a 1-unit region never
/// dispatches anyway, so 2 preserves historical behavior unless a call
/// site asks for a higher per-region floor). Raising it trades parallelism
/// of small regions for lower dispatch overhead — results are bit-identical
/// either way (dispatch width never affects values).
pub fn grain_floor() -> usize {
    *GRAIN.get_or_init(|| {
        if let Ok(v) = std::env::var("CHEETAH_PAR_GRAIN") {
            if let Ok(g) = v.trim().parse::<usize>() {
                if g > 0 {
                    return g;
                }
            }
        }
        2
    })
}

/// [`map_indexed`] with a per-region grain heuristic: when `n` is below
/// `max(min_units, grain_floor())` the region runs as a plain sequential
/// loop on the caller's thread — no pool submission, no condvar wakeups.
///
/// This is for regions whose *unit* cost can be tiny relative to fork-join
/// overhead (FC-tail grids with a couple of ciphertexts, per-channel block
/// sums on short streams): the caller states the region size below which
/// dispatch loses more than it gains, and `CHEETAH_PAR_GRAIN` lets an
/// operator raise the floor fleet-wide. Output is exactly
/// `[f(0), …, f(n-1)]` in either mode.
pub fn map_indexed_grained<R: Send, F: Fn(usize) -> R + Sync>(
    n: usize,
    min_units: usize,
    f: F,
) -> Vec<R> {
    if n < min_units.max(grain_floor()) {
        crate::obs::inc("par.regions.inline");
        return (0..n).map(f).collect();
    }
    map_indexed(n, f)
}

/// Parallel map over a slice, preserving order.
pub fn map_collect<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    map_indexed(items.len(), |i| f(i, &items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::MutexGuard;

    /// `CONFIGURED` is process-global and `cargo test` runs tests
    /// concurrently in one binary: every test that mutates the thread
    /// count must hold this lock, or another test's `set_threads` lands
    /// mid-assertion.
    fn threads_lock() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn join_returns_both_results() {
        let (a, b) = join(|| 6 * 7, || "hi".to_string());
        assert_eq!(a, 42);
        assert_eq!(b, "hi");
    }

    #[test]
    fn nested_join_completes_and_is_correct() {
        // Depth-3 nesting with every leaf doing real work: exercises the
        // caller-drains-its-own-region rule that makes nesting deadlock-free.
        fn sum_tree(lo: u64, hi: u64) -> u64 {
            if hi - lo <= 64 {
                return (lo..hi).sum();
            }
            let mid = lo + (hi - lo) / 2;
            let (a, b) = join(|| sum_tree(lo, mid), || sum_tree(mid, hi));
            a + b
        }
        let n = 10_000u64;
        assert_eq!(sum_tree(0, n), n * (n - 1) / 2);
    }

    #[test]
    fn for_each_chunked_covers_every_index_once() {
        // Odd length, odd grain: chunk math must still cover 0..len exactly.
        for (len, grain) in [(0usize, 3usize), (1, 3), (7, 2), (101, 13), (4096, 1000)] {
            let hits: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
            for_each_chunked(len, grain, |lo, hi| {
                assert!(lo < hi && hi <= len);
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} (len={len}, grain={grain})");
            }
        }
    }

    #[test]
    fn chunk_mut_handles_empty_and_ragged_tails() {
        let mut empty: Vec<u32> = Vec::new();
        for_each_chunk_mut(&mut empty, 4, |_, _| panic!("no chunks for empty input"));

        // 10 elements in chunks of 4 → chunk lens 4, 4, 2.
        let mut v: Vec<usize> = vec![0; 10];
        for_each_chunk_mut(&mut v, 4, |ci, chunk| {
            assert_eq!(chunk.len(), if ci == 2 { 2 } else { 4 });
            for (k, slot) in chunk.iter_mut().enumerate() {
                *slot = ci * 4 + k;
            }
        });
        assert_eq!(v, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_preserves_order_at_any_thread_count() {
        let _guard = threads_lock();
        let want: Vec<u64> = (0..500u64).map(|i| i * i).collect();
        for t in [1usize, 2, 8] {
            set_threads(t);
            let got = map_indexed(500, |i| (i as u64) * (i as u64));
            assert_eq!(got, want, "threads={t}");
        }
        set_threads(0);
    }

    #[test]
    fn grained_map_matches_map_and_stays_on_caller_below_floor() {
        let _guard = threads_lock();
        set_threads(8);
        // Above the floor: same values as plain map_indexed.
        let want: Vec<u64> = (0..100u64).map(|i| i * 3).collect();
        assert_eq!(map_indexed_grained(100, 4, |i| (i as u64) * 3), want);
        // Below the per-region floor: every unit runs on the caller thread
        // (no dispatch), and the values are still exact.
        let caller = std::thread::current().id();
        let ids = map_indexed_grained(3, 8, |i| (i, std::thread::current().id()));
        assert_eq!(ids.len(), 3);
        for (i, (idx, id)) in ids.iter().enumerate() {
            assert_eq!(*idx, i);
            assert_eq!(*id, caller, "unit {i} left the caller thread");
        }
        // Empty and single-unit regions degenerate cleanly.
        assert!(map_indexed_grained(0, 4, |i| i).is_empty());
        assert_eq!(map_indexed_grained(1, 4, |i| i), vec![0]);
        set_threads(0);
    }

    #[test]
    fn map_collect_maps_slices() {
        let items = vec![1i64, -2, 3];
        assert_eq!(map_collect(&items, |i, &v| v + i as i64), vec![1, -1, 5]);
        let none: Vec<i64> = Vec::new();
        assert!(map_collect(&none, |_, &v| v).is_empty());
    }

    #[test]
    fn panics_propagate_to_the_caller() {
        let res = std::panic::catch_unwind(|| {
            for_each_chunked(64, 1, |lo, _| {
                if lo >= 32 {
                    panic!("chunk boom");
                }
            });
        });
        assert!(res.is_err(), "worker panic must re-raise on the caller");
    }

    #[test]
    fn with_threads_scopes_to_the_calling_thread_and_restores() {
        let _guard = threads_lock();
        set_threads(4);
        assert_eq!(threads(), 4);
        assert_eq!(with_threads(2, threads), 2);
        assert_eq!(threads(), 4, "scope must not leak past its closure");
        with_threads(1, || {
            assert_eq!(threads(), 1);
            with_threads(3, || assert_eq!(threads(), 3, "scopes nest"));
            assert_eq!(threads(), 1, "inner scope restores the outer one");
        });
        assert_eq!(with_threads(0, threads), 4, "0 is a no-op scope");
        // …and a no-op even when nested: it must not cancel the enclosing
        // pin (SecureServer workers call with_threads(cfg.threads) with 0).
        with_threads(2, || {
            assert_eq!(with_threads(0, threads), 2, "0 must keep the outer scope");
        });
        // The scope is per-thread: another thread still sees the global.
        with_threads(2, || {
            let other = std::thread::spawn(threads).join().unwrap();
            assert_eq!(other, 4);
        });
        set_threads(0);
    }

    /// `EngineBuilder::threads(n)` must scope, not mutate the global —
    /// the regression this PR exists to prevent (a builder resizing a
    /// live server's pool). Lives here because it needs `threads_lock`.
    #[test]
    fn engine_builder_threads_is_scoped_not_global() {
        use crate::engine::{Backend, EngineBuilder, InferenceEngine};
        use crate::nn::{Layer, Network, Tensor};
        let _guard = threads_lock();
        set_threads(4);
        let mut net = Network {
            name: "scope-test".into(),
            input_shape: (1, 3, 3),
            layers: vec![Layer::fc(2)],
        };
        net.init_weights(3);
        let mut engine = EngineBuilder::new(Backend::PlaintextQuantized)
            .network(net)
            .threads(2)
            .build()
            .expect("engine build");
        assert_eq!(threads(), 4, "build() must not touch the global");
        let input = Tensor::from_vec(vec![0.5; 9], 1, 3, 3);
        engine.infer(&input).expect("inference");
        engine.infer_batch(&[input]).expect("batch");
        assert_eq!(threads(), 4, "engine calls must not leak their scope");
        set_threads(0);
    }

    #[test]
    fn with_threads_restores_on_panic() {
        let _guard = threads_lock();
        set_threads(5);
        let res = std::panic::catch_unwind(|| with_threads(2, || panic!("boom")));
        assert!(res.is_err());
        assert_eq!(threads(), 5, "panic inside the scope must still restore");
        set_threads(0);
    }

    #[test]
    fn scoped_single_thread_is_sequential_in_order() {
        let _guard = threads_lock();
        set_threads(8);
        with_threads(1, || {
            let order = Mutex::new(Vec::new());
            for_each_chunked(10, 1, |lo, hi| {
                for i in lo..hi {
                    order.lock().unwrap().push(i);
                }
            });
            assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
        });
        set_threads(0);
    }

    #[test]
    fn single_thread_is_sequential_in_order() {
        let _guard = threads_lock();
        set_threads(1);
        let order = Mutex::new(Vec::new());
        for_each_chunked(10, 1, |lo, hi| {
            for i in lo..hi {
                order.lock().unwrap().push(i);
            }
        });
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
        set_threads(0);
    }
}
