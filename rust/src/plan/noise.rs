//! Static worst-case noise and slot-magnitude analysis for a compiled
//! CHEETAH protocol run.
//!
//! Two independent budgets decide whether a parameter set `(n, q, p)` can
//! run a network correctly:
//!
//! 1. **Ciphertext noise** — BFV decryption is exact while the accumulated
//!    noise stays below `q/(2p)`. Each homomorphic op grows the noise by a
//!    bounded factor; the per-op rules below compose over the op sequence
//!    of [`ProtocolSpec::compile`]'s steps.
//! 2. **Slot magnitude** — every decrypted slot is interpreted as a
//!    *centered* value in `±(p−1)/2`. The obscured product `k·v·x + b`
//!    must stay inside that range per slot (block sums happen client-side
//!    in `i64` and are unconstrained by `p`).
//!
//! ## Per-op noise rules (worst case, in bits)
//!
//! | op                 | rule                                   | CHEETAH count/step |
//! |--------------------|----------------------------------------|--------------------|
//! | fresh encryption   | [`FRESH_NOISE_BITS`] (≈ `6σ` error)    | `num_in_cts`       |
//! | `MultPlain`        | `+ log2(n) + log2(p)` (operand coeffs  | `c_o · num_in_cts` |
//! |                    | lie in `[0, p)` after encoding)        |                    |
//! | `AddPlain`/ct-add  | [`ADD_CHAIN_SLACK_BITS`] for the whole | `c_o · num_in_cts` |
//! |                    | additive tail of a step                |                    |
//! | `Perm`/key-switch  | [`key_switch_growth_bits`] — **unused**| 0 (by construction)|
//!
//! The zero-Perm count is CHEETAH's headline property; the op counts per
//! step are cross-checked against the closed forms in
//! [`crate::complexity`] by the tests in this module, and the noise rules
//! are validated *empirically* against [`crate::phe::Encryptor::noise_bits`]
//! measurements on every zoo network (the model must always be an upper
//! bound on the measurement).
//!
//! ## Activation-bound tracking
//!
//! The slot-magnitude budget needs a bound on the true activation entering
//! each step. The analysis threads a value-domain bound `B` through the
//! steps:
//!
//! * the input is clamped by quantization to `±x_max`;
//! * a linear step bounds its output by `max_o Σ_t |k_q[o][t]|/2^k · B`
//!   computed from the **actual quantized weights** (the worst-case
//!   `k_max`-clamp bound would falsely reject networks whose weights are
//!   tiny, e.g. wide FC layers under He initialization);
//! * every hidden recovery re-encodes through the scrambled value `y`,
//!   which the client clamps at `±y_max`; with the blind `v₁ = ±2^j`,
//!   `j ∈ {-1,0,1}`, the recovered activation is bounded by
//!   `|y·v₂| ≤ 2·y_max` — so the post-step bound is
//!   `min(linear bound, 2·y_max) + ε`;
//! * a residual step adds its saved input shares: `B ← B_out + B_in`;
//! * a pool (fused or standalone local step) *sum*-pools shares:
//!   `B ← B · size²` (the divisor is folded into the next layer's
//!   pre-divided weights, which the quantized-row scan above sees).

use crate::fixed::ScalePlan;
use crate::nn::{Layer, Network};
use crate::phe::Params;
use crate::protocol::cheetah::server::NOISE_BOUND;
use crate::protocol::cheetah::{LinearSpec, ProtocolSpec, SpecError, StepSpec};

/// Worst-case fresh symmetric-encryption noise in bits. The error sampler
/// draws `e` with σ ≈ 3.2; `|e| ≤ 2^8` is a ≥ 80σ bound — unreachable in
/// practice, and the empirical validation tests assert measurements stay
/// below it.
pub const FRESH_NOISE_BITS: f64 = 8.0;

/// Slack covering the whole additive tail of one step: the `AddPlain` of
/// the server's share operand, plus the ciphertext-ciphertext add and
/// `AddPlain` of the client's recovery combination (each add at most
/// doubles the noise; three bits cover the worst chain either party runs
/// within one step).
pub const ADD_CHAIN_SLACK_BITS: f64 = 3.0;

/// Noise growth of one `MultPlain` in bits: the operand polynomial's
/// coefficients lie in `[0, p)` after batching encoding (negacyclic
/// convolution by `n` coefficients), so `‖e·op‖∞ ≤ n · ‖e‖∞ · p`.
///
/// Slot-value bounds on the operand do **not** help here: the inverse NTT
/// of the encoder spreads bounded slot values across full-range
/// coefficients, so `p` is the only sound coefficient bound.
pub fn mult_plain_growth_bits(params: &Params) -> f64 {
    params.log_n as f64 + params.p_bits() as f64
}

/// Noise growth of one key-switch (`Perm`) in bits — the rule GAZELLE-style
/// rotations would pay per hop. CHEETAH's op sequence contains **zero**
/// permutations (asserted against [`crate::complexity`] by the tests
/// here), so this rule never enters a budget; it is kept so the table is
/// complete and a future rotation-based step cannot silently omit it.
pub fn key_switch_growth_bits(params: &Params) -> f64 {
    params.log_n as f64 + params.q_bits() as f64 / 2.0
}

/// Worst-case noise (bits) of any ciphertext produced during one non-local
/// step: one fresh encryption, one `MultPlain`, and the step's additive
/// tail. Both the server's product ciphertexts and the client's recovery
/// ciphertexts are bounded by this (the recovery chain runs two
/// `MultPlain`s on *fresh* indicator ciphertexts, never on the product —
/// no step ever multiplies twice into the same ciphertext).
pub fn step_noise_bits(params: &Params) -> f64 {
    FRESH_NOISE_BITS + mult_plain_growth_bits(params) + ADD_CHAIN_SLACK_BITS
}

/// Noise allowance in bits: `⌊log2(q / 2p)⌋`, the same formula
/// [`crate::phe::Encryptor::noise_budget`] measures against. Decryption is
/// exact while accumulated noise stays below this.
pub fn noise_allowance_bits(params: &Params) -> f64 {
    (127 - (params.q() / (2 * params.p as u128)).leading_zeros()) as f64
}

/// One protocol step's static budget: op counts, the activation bound
/// threaded through it, and its two consumption-vs-allowance pairs.
#[derive(Clone, Debug)]
pub struct StepBudget {
    /// Step label (`step0:conv`, `step2:avgpool`, …).
    pub name: String,
    /// `MultPlain` count (cross-checked against [`crate::complexity`]).
    pub mults: u64,
    /// `AddPlain` count.
    pub adds: u64,
    /// `Perm` count — structurally zero for CHEETAH.
    pub perms: u64,
    /// Value-domain activation bound entering the step.
    pub input_bound: f64,
    /// Value-domain activation bound leaving the step (after ReLU clamp,
    /// residual add, and pooling).
    pub output_bound: f64,
    /// Predicted worst-case ciphertext noise after this step's ops (bits);
    /// zero for local steps, which touch no ciphertexts.
    pub noise_bits: f64,
    /// Noise allowance `log2(q/2p)` (bits).
    pub noise_allowance_bits: f64,
    /// `log2` of the worst decrypted slot magnitude this step can produce.
    pub magnitude_bits: f64,
    /// Slot allowance `log2((p−1)/2)` (bits).
    pub magnitude_allowance_bits: f64,
}

impl StepBudget {
    /// Unused noise allowance in bits (may be negative).
    pub fn noise_headroom_bits(&self) -> f64 {
        self.noise_allowance_bits - self.noise_bits
    }

    /// Unused slot-magnitude allowance in bits (may be negative).
    pub fn magnitude_headroom_bits(&self) -> f64 {
        self.magnitude_allowance_bits - self.magnitude_bits
    }

    /// The binding headroom: the smaller of the noise and magnitude
    /// headrooms.
    pub fn headroom_bits(&self) -> f64 {
        self.noise_headroom_bits().min(self.magnitude_headroom_bits())
    }
}

/// The full static budget of one network under one parameter set.
#[derive(Clone, Debug)]
pub struct NoiseBudgetReport {
    /// Network display name.
    pub network: String,
    /// The parameter set the budget was computed for.
    pub params: Params,
    /// Per-step budgets, in protocol order.
    pub steps: Vec<StepBudget>,
    /// Index of the step with the smallest headroom.
    pub worst: usize,
}

impl NoiseBudgetReport {
    /// The binding headroom across all steps (the worst step's).
    pub fn min_headroom_bits(&self) -> f64 {
        self.steps[self.worst].headroom_bits()
    }

    /// The step with the smallest headroom.
    pub fn worst_step(&self) -> &StepBudget {
        &self.steps[self.worst]
    }

    /// Render the per-step budget as an aligned text table, with the worst
    /// step marked.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{} @ n={} q={}b p={}b — noise allowance {:.0}b, slot allowance {:.1}b\n",
            self.network,
            self.params.n,
            self.params.q_bits(),
            self.params.p_bits(),
            self.steps.first().map(|s| s.noise_allowance_bits).unwrap_or(0.0),
            self.steps.first().map(|s| s.magnitude_allowance_bits).unwrap_or(0.0),
        ));
        out.push_str(&format!(
            "{:<16} {:>7} {:>7} {:>5} {:>9} {:>9} {:>8} {:>8} {:>9}\n",
            "step", "mults", "adds", "perms", "in|x|", "out|x|", "noise b", "slot b", "headroom"
        ));
        for (i, s) in self.steps.iter().enumerate() {
            out.push_str(&format!(
                "{:<16} {:>7} {:>7} {:>5} {:>9.2} {:>9.2} {:>8.1} {:>8.1} {:>8.2}b{}\n",
                s.name,
                s.mults,
                s.adds,
                s.perms,
                s.input_bound,
                s.output_bound,
                s.noise_bits,
                s.magnitude_bits,
                s.headroom_bits(),
                if i == self.worst { "  ◀ worst" } else { "" },
            ));
        }
        out
    }
}

/// Scan a step's actual quantized weights (same indexing and pool
/// pre-division as the server's operand build): returns
/// `(max tap |k_q|, max per-output-block Σ|k_q|)`.
fn quantized_row_stats(layer: &Layer, step: &StepSpec, plan: &ScalePlan) -> (i64, i64) {
    let div = step.weight_div;
    let (mut max_tap, mut max_row) = (0i64, 0i64);
    match &step.linear {
        LinearSpec::Conv(p) => {
            let (c_i, _, _) = p.in_shape;
            let r = p.kernel;
            for o in 0..p.out_shape.0 {
                let mut row = 0i64;
                for t in 0..p.block {
                    let i = t / (r * r);
                    let rem = t % (r * r);
                    let kq = plan.quant_k(layer.conv_w(c_i, r, o, i, rem / r, rem % r) / div).abs();
                    max_tap = max_tap.max(kq);
                    row += kq;
                }
                max_row = max_row.max(row);
            }
        }
        LinearSpec::Fc(p) => {
            for o in 0..p.n_o {
                let mut row = 0i64;
                for j in 0..p.n_i {
                    let kq = plan.quant_k(layer.fc_w(p.n_i, o, j) / div).abs();
                    max_tap = max_tap.max(kq);
                    row += kq;
                }
                max_row = max_row.max(row);
            }
        }
        LinearSpec::AvgPool { .. } => {}
    }
    (max_tap, max_row)
}

/// Compute the static noise/magnitude budget of `net` under `params`.
///
/// `epsilon` is the obscuring-noise bound the deployment will run with; it
/// enters the slot bound (the noise share `b` carries the target `v₁·δ`)
/// and the activation bound (each recovery perturbs the value by at most
/// `ε`). Passing the *largest* ε the deployment may use keeps the budget
/// an upper bound. A network the protocol cannot express surfaces as the
/// compiler's typed [`SpecError`].
pub fn analyze(
    net: &Network,
    params: &Params,
    plan: &ScalePlan,
    epsilon: f64,
) -> Result<NoiseBudgetReport, SpecError> {
    let spec = ProtocolSpec::compile(net)?;
    let n = params.n;
    let half = ((params.p - 1) / 2) as f64;
    let mag_allow = half.log2();
    let noise_allow = noise_allowance_bits(params);
    // Worst multiplicative blind magnitude: v₁ = ±2 at the v scale.
    let v_int_max = 2.0 * plan.v.factor();
    // Per-slot additive noise share: |b| ≤ NOISE_BOUND + |v₁·δ|, plus one
    // integer of quantization rounding.
    let noise_slack = NOISE_BOUND as f64 * (1.0 + 2.0 * epsilon) + 1.0;

    let mut bound = plan.x_max;
    let mut steps = Vec::with_capacity(spec.steps.len());
    for (si, step) in spec.steps.iter().enumerate() {
        let name = format!(
            "step{si}:{}",
            match &step.linear {
                LinearSpec::Conv(_) => "conv",
                LinearSpec::Fc(_) => "fc",
                LinearSpec::AvgPool { .. } => "avgpool",
            }
        );
        let budget = if let LinearSpec::AvgPool { size, .. } = &step.linear {
            // Local step: no ciphertexts at all; both parties sum-pool
            // their own shares, so the only constraint is that the pooled
            // *true* value still fits a slot when the next step runs.
            let out_bound = bound * (size * size) as f64;
            let b = StepBudget {
                name,
                mults: 0,
                adds: 0,
                perms: 0,
                input_bound: bound,
                output_bound: out_bound,
                noise_bits: 0.0,
                noise_allowance_bits: noise_allow,
                magnitude_bits: (out_bound * plan.x.factor()).max(1.0).log2(),
                magnitude_allowance_bits: mag_allow,
            };
            bound = out_bound;
            b
        } else {
            let layer = &net.layers[step.layer_idx];
            let (max_tap, max_row) = quantized_row_stats(layer, step, plan);
            // Worst decrypted slot: k_q · v₁ · x + b at the product scale.
            let x_int = bound * plan.x.factor();
            let slot = max_tap as f64 * v_int_max * x_int + noise_slack;
            let in_cts = step.linear.num_in_cts(n) as u64;
            let ops = step.linear.num_channels() as u64 * in_cts;
            let mut out_bound = (max_row as f64 / plan.k.factor()) * bound;
            if si != spec.last_idx() {
                // Hidden steps re-encode through y, clamped at ±y_max; the
                // recovered activation is bounded by |y·v₂| ≤ 2·y_max (+ε
                // obscuring drift) whatever the linear output was.
                out_bound = out_bound.min(2.0 * plan.y_max) + epsilon;
            }
            if step.residual_add {
                out_bound += bound;
            }
            if let Some(s) = step.pool_after {
                out_bound *= (s * s) as f64;
            }
            let b = StepBudget {
                name,
                mults: ops,
                adds: ops,
                perms: 0,
                input_bound: bound,
                output_bound: out_bound,
                noise_bits: step_noise_bits(params),
                noise_allowance_bits: noise_allow,
                magnitude_bits: slot.log2(),
                magnitude_allowance_bits: mag_allow,
            };
            bound = out_bound;
            b
        };
        steps.push(budget);
    }
    let worst = steps
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.headroom_bits().total_cmp(&b.headroom_bits()))
        .map(|(i, _)| i)
        .unwrap_or(0);
    Ok(NoiseBudgetReport { network: net.name.clone(), params: *params, steps, worst })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::complexity::{ConvShape, FcShape};
    use crate::nn::{Network, NetworkArch};

    fn default_report(arch: NetworkArch) -> NoiseBudgetReport {
        let net = Network::build(arch, 3);
        analyze(&net, &Params::default_params(), &ScalePlan::default_plan(), 0.01)
            .expect("zoo nets compile")
    }

    /// The analyzer's per-step op counts must agree with the closed-form
    /// complexity model (Table 2's CH-MIMO / CH-FC rows) — same counting,
    /// two independent derivations.
    #[test]
    fn op_counts_match_complexity_model() {
        for arch in [NetworkArch::NetA, NetworkArch::NetB, NetworkArch::NetRes] {
            let net = Network::build(arch, 3);
            let params = Params::default_params();
            let spec = ProtocolSpec::compile(&net).unwrap();
            let report = analyze(&net, &params, &ScalePlan::default_plan(), 0.01).unwrap();
            assert_eq!(report.steps.len(), spec.steps.len());
            for (b, s) in report.steps.iter().zip(&spec.steps) {
                let want = match &s.linear {
                    LinearSpec::Conv(p) => ConvShape {
                        c_i: p.in_shape.0 as u64,
                        c_o: p.out_shape.0 as u64,
                        r: p.kernel as u64,
                        // `hw` is the output-position count (the packing's
                        // n_pos) so the stream length matches at any stride.
                        hw: p.n_pos as u64,
                        n: params.n as u64,
                    }
                    .cheetah(),
                    LinearSpec::Fc(p) => FcShape {
                        n_i: p.n_i as u64,
                        n_o: p.n_o as u64,
                        n: params.n as u64,
                    }
                    .cheetah(),
                    LinearSpec::AvgPool { .. } => crate::complexity::Counts::default(),
                };
                assert_eq!(b.mults, want.mult, "{}: {}", net.name, b.name);
                assert_eq!(b.adds, want.add, "{}: {}", net.name, b.name);
                assert_eq!(b.perms, want.perm, "{}: {}", net.name, b.name);
                assert_eq!(b.perms, 0, "CHEETAH steps must never permute");
            }
        }
    }

    /// Activation-bound threading: pools multiply the bound, hidden
    /// recoveries clamp it at `2·y_max + ε`, residual steps accumulate it.
    #[test]
    fn bound_tracking_follows_protocol_shape() {
        let plan = ScalePlan::default_plan();
        // NetPool opens with a standalone 2×2 pool: bound quadruples.
        let pool = default_report(NetworkArch::NetPool);
        assert_eq!(pool.steps[0].name, "step0:avgpool");
        assert_eq!(pool.steps[0].input_bound, plan.x_max);
        assert_eq!(pool.steps[0].output_bound, plan.x_max * 4.0);
        assert_eq!(pool.steps[0].noise_bits, 0.0);
        assert_eq!(pool.steps[0].mults, 0);

        // NetRes residual chain: the bound entering each block grows by at
        // most the recovery clamp per block, and grows monotonically.
        let res = default_report(NetworkArch::NetRes);
        let clamp = 2.0 * plan.y_max + 0.01;
        for w in res.steps.windows(2) {
            assert!(w[1].input_bound >= w[0].input_bound, "residual bound must accumulate");
            assert!(w[1].input_bound <= w[0].input_bound + clamp + 1e-9);
        }
        // No hidden non-residual step can exceed the recovery clamp.
        let a = default_report(NetworkArch::NetA);
        for s in &a.steps[..a.steps.len() - 1] {
            assert!(s.output_bound <= clamp + 1e-9, "{}: {}", a.network, s.output_bound);
        }
    }

    /// Shrinking q reduces only the noise allowance; shrinking p reduces
    /// the slot allowance (and the noise cost with it).
    #[test]
    fn allowances_track_params() {
        let d = Params::default_params();
        let small_q = Params::with_q_bits(4096, 23, 30);
        assert!(noise_allowance_bits(&small_q) < noise_allowance_bits(&d));
        assert_eq!(small_q.p, d.p);
        let small_p = Params::new(4096, 18);
        assert!(small_p.p < d.p);
        assert!(step_noise_bits(&small_p) < step_noise_bits(&d));
        // The key-switch rule exists (for the table) but no CHEETAH step
        // ever pays it.
        assert!(key_switch_growth_bits(&d) > 0.0);
    }

    /// The rendered table carries every step and marks the worst one.
    #[test]
    fn render_is_complete() {
        let r = default_report(NetworkArch::NetB);
        let text = r.render();
        for s in &r.steps {
            assert!(text.contains(&s.name), "missing {} in:\n{text}", s.name);
        }
        assert!(text.contains("◀ worst"));
        assert!(r.min_headroom_bits().is_finite());
        assert_eq!(
            r.worst_step().headroom_bits(),
            r.steps.iter().map(|s| s.headroom_bits()).fold(f64::INFINITY, f64::min)
        );
    }
}
