//! Parameter planner: static noise-budget analysis and automatic RLWE
//! parameter selection.
//!
//! Choosing `(n, q, p)` for a CHEETAH deployment used to be folklore —
//! run the default set and hope every slot stays in range. This subsystem
//! makes the decision static and typed:
//!
//! * [`noise`] models, per protocol step, the worst-case ciphertext noise
//!   (per-op composition rules, cross-checked against
//!   [`crate::complexity`]) and the worst-case decrypted slot magnitude
//!   (actual quantized weights, blinding, and additive-noise bounds),
//!   producing a per-step [`NoiseBudgetReport`];
//! * [`planner`] walks a cost-ordered ladder of vetted parameter
//!   [`Rung`]s and returns the cheapest one whose worst step clears a
//!   safety margin — or a typed [`PlanError::Infeasible`] naming the
//!   binding step, raised *before* any key or ciphertext exists;
//! * [`ParamsChoice`] is the knob engines, servers, and CLIs thread
//!   through: `default` (bit-compatible with every pinned-seed artifact),
//!   `big`, an explicit set, or `auto` (run the planner).
//!
//! The model is validated empirically: the planner tests replay every zoo
//! network at its chosen rung and assert the measured noise of every
//! ciphertext ([`crate::phe::Encryptor::noise_bits`]) stays within the
//! per-step prediction.
#![warn(missing_docs)]

pub mod noise;
pub mod planner;

pub use noise::{
    analyze, key_switch_growth_bits, mult_plain_growth_bits, noise_allowance_bits,
    step_noise_bits, NoiseBudgetReport, StepBudget, ADD_CHAIN_SLACK_BITS, FRESH_NOISE_BITS,
};
pub use planner::{
    ladder, ParamsChoice, Plan, PlanError, Rung, DEFAULT_MARGIN_BITS, PLANNING_EPSILON,
};
