//! Automatic RLWE parameter selection: a ladder of vetted parameter rungs,
//! climbed until the static budget of [`super::noise::analyze`] clears a
//! configurable safety margin.
//!
//! ## The ladder
//!
//! | rung       | n    | p bits | q bits (2 primes) | security floor |
//! |------------|------|--------|-------------------|----------------|
//! | `default`  | 4096 | 23     | ~90               | 128            |
//! | `wide-p`   | 4096 | 26     | ~90               | 128            |
//! | `big`      | 8192 | 23     | ~90               | 192            |
//! | `big-wide` | 8192 | 26     | ~90               | 192            |
//!
//! Rungs are ordered by cost (ring degree dominates; a wider plaintext
//! modulus is free at fixed `n`), so the first clearing rung is the
//! cheapest. Security floors are conservative reads of the homomorphic
//! encryption standard tables: ternary-secret `(n=4096, log q ≤ 109)` and
//! `(n=8192, log q ≤ 218)` both meet 128-bit security, and our ~90-bit `q`
//! sits far inside those ceilings.
//!
//! ## Margin policy
//!
//! A rung is accepted when the *worst step's* headroom — the smaller of
//! its noise and slot-magnitude headrooms — is at least
//! [`DEFAULT_MARGIN_BITS`]. The static model is already worst-case, so the
//! margin only absorbs model drift (weight retraining, a changed ε), not
//! randomness. When no rung clears, planning fails with
//! [`PlanError::Infeasible`] **before** any ciphertext is built — a
//! mis-parameterized deployment is refused instead of silently decrypting
//! garbage.

use super::noise::{analyze, NoiseBudgetReport};
use crate::fixed::ScalePlan;
use crate::nn::Network;
use crate::phe::Params;
use crate::protocol::cheetah::SpecError;

/// Default safety margin in bits on the worst step's headroom.
pub const DEFAULT_MARGIN_BITS: f64 = 2.0;

/// Obscuring-noise bound assumed during planning. Deployments run with
/// ε ≤ 0.05 in every shipped configuration; planning with the ceiling
/// keeps the chosen rung valid for all of them.
pub const PLANNING_EPSILON: f64 = 0.05;

/// One vetted parameter rung of the ladder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rung {
    /// Short rung name (`default`, `wide-p`, `big`, `big-wide`).
    pub name: &'static str,
    /// Ring degree.
    pub n: usize,
    /// Plaintext modulus width passed to [`Params::with_q_bits`].
    pub plain_bits: u32,
    /// Per-prime ciphertext modulus width (two RNS primes).
    pub q_bits: u32,
    /// Conservative security floor in bits (HE-standard tables, ternary
    /// secret) — see the module docs.
    pub security_bits: u32,
}

impl Rung {
    /// Instantiate the rung's concrete parameter set.
    pub fn params(&self) -> Params {
        Params::with_q_bits(self.n, self.plain_bits, self.q_bits)
    }
}

/// The candidate ladder, cheapest rung first (see module docs).
pub fn ladder() -> [Rung; 4] {
    [
        Rung { name: "default", n: 4096, plain_bits: 23, q_bits: 45, security_bits: 128 },
        Rung { name: "wide-p", n: 4096, plain_bits: 26, q_bits: 45, security_bits: 128 },
        Rung { name: "big", n: 8192, plain_bits: 23, q_bits: 45, security_bits: 192 },
        Rung { name: "big-wide", n: 8192, plain_bits: 26, q_bits: 45, security_bits: 192 },
    ]
}

/// Why planning failed.
#[derive(Clone, Debug, PartialEq)]
pub enum PlanError {
    /// The network cannot be compiled into a protocol spec at all.
    Spec(SpecError),
    /// No candidate cleared the margin; `step` is the binding step of the
    /// last (largest) rung tried and `deficit_bits` how far below the
    /// margin its headroom fell.
    Infeasible {
        /// Label of the binding step (`step3:conv`, …).
        step: String,
        /// Bits of headroom missing (relative to the requested margin).
        deficit_bits: f64,
    },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Spec(e) => write!(f, "planning failed: {e}"),
            PlanError::Infeasible { step, deficit_bits } => write!(
                f,
                "no parameter rung clears the budget: {step} is short {deficit_bits:.2} bits \
                 of headroom on the largest rung"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

impl From<SpecError> for PlanError {
    fn from(e: SpecError) -> Self {
        PlanError::Spec(e)
    }
}

/// How an engine or server picks its RLWE parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ParamsChoice {
    /// The bit-compatible default set ([`Params::default_params`]).
    Default,
    /// A caller-supplied explicit set (used as-is, no feasibility gate).
    Explicit(Params),
    /// Run the planner and take the cheapest clearing rung.
    Auto,
}

impl Default for ParamsChoice {
    fn default() -> Self {
        ParamsChoice::Default
    }
}

impl ParamsChoice {
    /// Parse a CLI value: `auto`, `default`, or `big`
    /// ([`Params::big_ring`]).
    pub fn parse(s: &str) -> Option<ParamsChoice> {
        match s {
            "auto" => Some(ParamsChoice::Auto),
            "default" => Some(ParamsChoice::Default),
            "big" => Some(ParamsChoice::Explicit(Params::big_ring())),
            _ => None,
        }
    }

    /// Resolve to a concrete parameter set for `net`. `Auto` runs the
    /// planner and also returns the winning [`Plan`] (rung + report) for
    /// display; the other choices pass through untouched.
    pub fn resolve(&self, net: &Network) -> Result<(Params, Option<Plan>), PlanError> {
        match self {
            ParamsChoice::Default => Ok((Params::default_params(), None)),
            ParamsChoice::Explicit(p) => Ok((*p, None)),
            ParamsChoice::Auto => {
                let plan = Plan::for_network(net)?;
                Ok((plan.params, Some(plan)))
            }
        }
    }
}

/// A successful parameter selection: the winning rung, its concrete
/// parameters, and the budget report that cleared the margin.
#[derive(Clone, Debug)]
pub struct Plan {
    /// The accepted ladder rung.
    pub rung: Rung,
    /// Concrete parameters of that rung.
    pub params: Params,
    /// The static budget under those parameters.
    pub report: NoiseBudgetReport,
    /// The margin the report was required to clear.
    pub margin_bits: f64,
}

impl Plan {
    /// Select the cheapest ladder rung for `net` under the default scale
    /// plan, planning ε, and margin.
    pub fn for_network(net: &Network) -> Result<Plan, PlanError> {
        Self::for_network_with(net, &ScalePlan::default_plan(), PLANNING_EPSILON, DEFAULT_MARGIN_BITS)
    }

    /// Like [`Plan::for_network`] with explicit scale plan, obscuring ε,
    /// and margin.
    pub fn for_network_with(
        net: &Network,
        plan: &ScalePlan,
        epsilon: f64,
        margin_bits: f64,
    ) -> Result<Plan, PlanError> {
        let mut last_err = None;
        for rung in ladder() {
            let params = rung.params();
            match Self::check_with(net, &params, plan, epsilon, margin_bits) {
                Ok(report) => return Ok(Plan { rung, params, report, margin_bits }),
                Err(e @ PlanError::Infeasible { .. }) => last_err = Some(e),
                Err(e) => return Err(e),
            }
        }
        Err(last_err.expect("ladder is non-empty"))
    }

    /// Gate an *explicit* parameter set: Ok with the budget report when
    /// every step clears the default margin, a typed
    /// [`PlanError::Infeasible`] otherwise — callers refuse to build any
    /// ciphertext machinery on Err, so an undersized set fails loudly
    /// before it can decrypt garbage.
    pub fn check(net: &Network, params: &Params) -> Result<NoiseBudgetReport, PlanError> {
        Self::check_with(net, params, &ScalePlan::default_plan(), PLANNING_EPSILON, DEFAULT_MARGIN_BITS)
    }

    /// [`Plan::check`] with explicit scale plan, ε, and margin.
    pub fn check_with(
        net: &Network,
        params: &Params,
        plan: &ScalePlan,
        epsilon: f64,
        margin_bits: f64,
    ) -> Result<NoiseBudgetReport, PlanError> {
        let report = analyze(net, params, plan, epsilon)?;
        let headroom = report.min_headroom_bits();
        if headroom < margin_bits {
            return Err(PlanError::Infeasible {
                step: report.worst_step().name.clone(),
                deficit_bits: margin_bits - headroom,
            });
        }
        Ok(report)
    }

    /// Render the chosen rung plus the per-step headroom table.
    pub fn render(&self) -> String {
        format!(
            "rung '{}' (n={}, p={} bits, q={} bits, ≥{}-bit security), margin {:.1} bits, \
             worst headroom {:.2} bits\n{}",
            self.rung.name,
            self.params.n,
            self.params.p_bits(),
            self.params.q_bits(),
            self.rung.security_bits,
            self.margin_bits,
            self.report.min_headroom_bits(),
            self.report.render()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::NetworkArch;

    /// Build the zoo at test scale: big ImageNet-era nets at the 0.125
    /// factor the benchmarks use, everything else full size.
    fn zoo_net(arch: NetworkArch, seed: u64) -> Network {
        match arch {
            NetworkArch::AlexNet | NetworkArch::Vgg16 => Network::build_scaled(arch, seed, 0.125),
            _ => Network::build(arch, seed),
        }
    }

    #[test]
    fn ladder_is_cost_ordered_and_secure() {
        let rungs = ladder();
        assert_eq!(rungs[0].params(), Params::default_params(), "rung 0 is bit-compatible");
        for w in rungs.windows(2) {
            let cheaper = (w[0].n, w[0].plain_bits);
            let dearer = (w[1].n, w[1].plain_bits);
            assert!(cheaper < dearer, "ladder must be cost-ordered: {cheaper:?} vs {dearer:?}");
        }
        for r in rungs {
            assert!(r.security_bits >= 128, "{}: below the security floor", r.name);
            let p = r.params();
            assert_eq!(p.n, r.n);
            assert_eq!(p.p_bits(), r.plain_bits);
            // ~90-bit q from two 45-bit primes on every rung.
            assert!(p.q_bits() >= 88, "{}: q only {} bits", r.name, p.q_bits());
        }
    }

    /// Every pre-existing zoo network runs on the default rung; the
    /// residual NetRes — whose skip chain accumulates activation magnitude
    /// past the default slot budget — is the entry that forces a bigger
    /// rung (the wide-p plaintext modulus).
    #[test]
    fn auto_keeps_zoo_on_default_but_netres_climbs() {
        let default_p = Params::default_params();
        for arch in NetworkArch::all() {
            let net = zoo_net(arch, 5);
            let plan = Plan::for_network(&net).expect("every zoo net must be plannable");
            if arch == NetworkArch::NetRes {
                assert_ne!(plan.rung.name, "default", "NetRes must outgrow the default rung");
                assert!(
                    plan.params.p_bits() > default_p.p_bits(),
                    "NetRes needs a wider plaintext modulus, got {} bits",
                    plan.params.p_bits()
                );
            } else {
                assert_eq!(
                    plan.rung.name, "default",
                    "{}: expected the default rung, got '{}'",
                    net.name, plan.rung.name
                );
                assert_eq!(plan.params, default_p);
            }
            assert!(plan.report.min_headroom_bits() >= plan.margin_bits);
            let text = plan.render();
            assert!(text.contains(plan.rung.name));
        }
    }

    /// Pinning NetRes to the default parameters is a typed refusal with the
    /// binding step named — checked statically, before any key or
    /// ciphertext exists.
    #[test]
    fn netres_on_default_params_is_infeasible() {
        let net = Network::build(NetworkArch::NetRes, 5);
        match Plan::check(&net, &Params::default_params()) {
            Err(PlanError::Infeasible { step, deficit_bits }) => {
                assert!(deficit_bits > 0.0);
                assert!(step.starts_with("step"), "binding step label: {step}");
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
    }

    /// An undersized ciphertext modulus fails the *noise* budget: the
    /// planner refuses the set statically instead of letting decryption
    /// produce garbage.
    #[test]
    fn undersized_q_is_noise_infeasible() {
        let small_q = Params::with_q_bits(4096, 23, 30);
        let net = Network::build(NetworkArch::NetA, 5);
        assert!(matches!(
            Plan::check(&net, &small_q),
            Err(PlanError::Infeasible { .. })
        ));
        // The binding constraint is noise, not magnitude.
        let report =
            super::analyze(&net, &small_q, &ScalePlan::default_plan(), PLANNING_EPSILON).unwrap();
        let worst = report.worst_step();
        assert!(worst.noise_headroom_bits() < worst.magnitude_headroom_bits());
        assert!(worst.noise_headroom_bits() < DEFAULT_MARGIN_BITS);
    }

    /// An undersized plaintext modulus fails the *slot-magnitude* budget.
    #[test]
    fn small_p_is_magnitude_infeasible() {
        let small_p = Params::new(4096, 16);
        let net = Network::build(NetworkArch::NetA, 5);
        assert!(matches!(
            Plan::check(&net, &small_p),
            Err(PlanError::Infeasible { .. })
        ));
        let report =
            super::analyze(&net, &small_p, &ScalePlan::default_plan(), PLANNING_EPSILON).unwrap();
        let worst = report.worst_step();
        assert!(worst.magnitude_headroom_bits() < worst.noise_headroom_bits());
        assert!(worst.magnitude_headroom_bits() < DEFAULT_MARGIN_BITS);
    }

    #[test]
    fn params_choice_parses_and_resolves() {
        assert_eq!(ParamsChoice::parse("auto"), Some(ParamsChoice::Auto));
        assert_eq!(ParamsChoice::parse("default"), Some(ParamsChoice::Default));
        assert_eq!(
            ParamsChoice::parse("big"),
            Some(ParamsChoice::Explicit(Params::big_ring()))
        );
        assert_eq!(ParamsChoice::parse("huge"), None);
        assert_eq!(ParamsChoice::default(), ParamsChoice::Default);

        let net = Network::build(NetworkArch::NetA, 5);
        let (p, plan) = ParamsChoice::Default.resolve(&net).unwrap();
        assert_eq!(p, Params::default_params());
        assert!(plan.is_none());
        let (p, _) = ParamsChoice::Explicit(Params::big_ring()).resolve(&net).unwrap();
        assert_eq!(p.n, 8192);
        let (p, plan) = ParamsChoice::Auto.resolve(&net).unwrap();
        assert_eq!(p, Params::default_params());
        assert_eq!(plan.unwrap().rung.name, "default");
    }

    /// Empirical validation of the static model: run every zoo network at
    /// its planner-chosen rung and assert the *measured* ciphertext noise
    /// ([`crate::phe::Encryptor::noise_bits`]) of every ciphertext the
    /// protocol produces stays at or below the per-step prediction. The
    /// model is worst-case, so a violation means the model (and therefore
    /// the planner) is unsound.
    #[test]
    fn measured_noise_stays_within_the_static_model() {
        use crate::nn::Tensor;
        use crate::phe::Context;
        use crate::protocol::cheetah::CheetahRunner;
        use std::sync::Arc;

        for arch in NetworkArch::all() {
            let net = zoo_net(arch, 11);
            let chosen = Plan::for_network(&net).expect("plannable");
            let ctx = Arc::new(Context::new(chosen.params));
            let mut runner =
                CheetahRunner::new(ctx, net.clone(), ScalePlan::default_plan(), 0.01, 7)
                    .expect("valid network");
            runner.run_offline();

            let (c, h, w) = net.input_shape;
            let len = c * h * w;
            let input = Tensor::from_vec(
                (0..len).map(|i| ((i * 2654435761) % 1024) as f64 / 256.0 - 2.0).collect(),
                c,
                h,
                w,
            );
            runner.client.begin_query(&input);
            runner.server.begin_query();
            for si in 0..runner.spec().steps.len() {
                let predicted = chosen.report.steps[si].noise_bits;
                let in_cts = runner.client.step_send(si);
                for (k, ct) in in_cts.iter().enumerate() {
                    let got = runner.client.enc.noise_bits(ct) as f64;
                    assert!(
                        got <= super::super::noise::FRESH_NOISE_BITS,
                        "{}: step {si} fresh ct {k}: measured {got}b > model {}b",
                        net.name,
                        super::super::noise::FRESH_NOISE_BITS
                    );
                }
                let out_cts = runner.server.step_linear(si, &in_cts);
                for (k, ct) in out_cts.iter().enumerate() {
                    let got = runner.client.enc.noise_bits(ct) as f64;
                    assert!(
                        got <= predicted,
                        "{}: step {si} product ct {k}: measured {got}b > predicted {predicted}b",
                        net.name
                    );
                }
                if let Some(rec) = runner.client.step_receive(si, &out_cts) {
                    for (k, ct) in rec.iter().enumerate() {
                        let got = runner.server.enc.noise_bits(ct) as f64;
                        assert!(
                            got <= predicted,
                            "{}: step {si} recovery ct {k}: measured {got}b > predicted \
                             {predicted}b",
                            net.name
                        );
                    }
                    runner.server.finish_nonlinear(si, &rec);
                } else if runner.spec().steps[si].is_local() {
                    runner.server.finish_local(si);
                }
            }
            // The run completed below budget: logits are well-defined.
            assert!(runner.client.logits().iter().all(|l| l.is_finite()));
        }
    }
}
