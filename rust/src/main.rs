//! `cheetah` — the leader CLI.
//!
//! ```text
//! cheetah serve         [--addr A] [--model netA] [--max-batch N]     serve a trained model over TCP (plaintext scoring)
//! cheetah serve-secure  [--addr A] [--model netA] [--pool-depth N]    serve the CHEETAH protocol over TCP (private inference)
//!                       [--pool-workers N] [--workers N] [--eps E]
//!                       [--seed S]  (blinding seed; default: OS entropy)
//!                       [--threads T]  (compute threads; 0 = all cores)
//!                       [--params auto|default|big]  (RLWE parameter policy; auto runs the planner)
//!                       [--reactor]  (readiness event loop instead of thread-per-connection; unix)
//!                       [--max-sessions N]  (reactor connection cap; default 4096)
//!                       [--stats-addr A]  (live telemetry endpoint; e.g. 127.0.0.1:9911)
//!                       [--drain-timeout-ms N]  (graceful-drain bound at shutdown; default 5000)
//!                       [--fault SPEC]  (deterministic fault injection, e.g. seed=7,disconnect=0.01;
//!                                        also readable from the CHEETAH_FAULT env var)
//! cheetah infer         [--backend B[,B...]] [--model netA] [--eps E]  inference through the unified engine API;
//!                       [--label D] [--seed S] [--threads T]           B ∈ {plaintext-float, plaintext-quantized,
//!                       [--params auto|default|big]                    cheetah, gazelle, gala, cheetah-net, all}
//! cheetah plan          [--network netA|netB|alexnet|vgg16|netRes|netPool|all]
//!                                                                     static noise/magnitude budget + chosen parameter rung
//! cheetah tables                                                      print the paper's analytic tables
//! cheetah bench-help                                                   how to regenerate every paper table/figure
//! ```
//!
//! `--threads` drives the crate-wide parallel runtime ([`cheetah::par`]):
//! per-channel ciphertext streams, NTT batches, and conv loops fan out over
//! that many threads (default `available_parallelism()`, overridable with
//! the `CHEETAH_THREADS` env var; `1` is the exact sequential path — the
//! arithmetic is bit-identical at every thread count).
//!
//! `infer` runs the same input through every requested backend via
//! [`cheetah::engine::EngineBuilder`] and prints one unified
//! [`cheetah::engine::EngineReport`] comparison table — the paper's
//! CHEETAH-vs-GAZELLE-vs-plaintext story in a single command.

use cheetah::coordinator::{BatchPolicy, Server};
use cheetah::engine::{comparison_table, Backend, EngineBuilder, InferenceEngine};
use cheetah::fixed::ScalePlan;
use cheetah::nn::{Network, NetworkArch, SyntheticDigits};
use cheetah::phe::Context;
use cheetah::plan::{ParamsChoice, Plan};
use cheetah::runtime::load_trained_network;
use cheetah::serve::{FaultSpec, PoolConfig, SecureConfig, SecureServer};
use std::sync::Arc;
use std::time::Duration;

fn arg(flag: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

/// Bare boolean flag (`--reactor`): present or not, no value.
fn has(flag: &str) -> bool {
    std::env::args().any(|a| a == flag)
}

/// Trained weights when `make artifacts` ran, otherwise a seeded random
/// network of the same architecture (still exercises the full protocol).
fn model_or_fallback(model: &str) -> Network {
    load_trained_network("artifacts", model).unwrap_or_else(|e| {
        eprintln!("artifacts unavailable ({e}); serving an untrained {model}");
        let arch = NetworkArch::from_key(model).unwrap_or(NetworkArch::NetA);
        Network::build(arch, 11)
    })
}

/// Parse `--params` and resolve it against `net`, printing the chosen rung
/// plus the per-step headroom table whenever the planner ran.
fn resolve_params(net: &Network) -> Result<cheetah::phe::Params, Box<dyn std::error::Error>> {
    let raw = arg("--params", "default");
    let choice = ParamsChoice::parse(&raw)
        .ok_or_else(|| format!("unknown --params value `{raw}` (expected auto|default|big)"))?;
    let (params, plan) = choice.resolve(net)?;
    match plan {
        Some(plan) => println!("{}", plan.render()),
        None if !matches!(choice, ParamsChoice::Default) => println!(
            "params: n={}, p={} bits, q={} bits",
            params.n,
            params.p_bits(),
            params.q_bits()
        ),
        None => {}
    }
    Ok(params)
}

/// The spatial scale the planner/CLI analyzes a zoo architecture at: the
/// ImageNet-sized nets run at 1/8 scale (the test/bench convention), the
/// MNIST-sized nets at full size.
fn plan_scale(arch: NetworkArch) -> f64 {
    match arch {
        NetworkArch::AlexNet | NetworkArch::Vgg16 => 0.125,
        _ => 1.0,
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cmd = std::env::args().nth(1).unwrap_or_else(|| "bench-help".into());
    match cmd.as_str() {
        "serve" => {
            let addr = arg("--addr", "127.0.0.1:7878");
            let model = arg("--model", "netA");
            let max_batch: usize = arg("--max-batch", "16").parse()?;
            let net = load_trained_network("artifacts", &model)?;
            println!("serving {} on {addr} (max batch {max_batch})", net.name);
            let server = Server::serve(
                net,
                &addr,
                BatchPolicy { max_batch, linger: Duration::from_millis(2) },
            )?;
            println!("listening on {} — Ctrl-C to stop", server.addr);
            loop {
                std::thread::sleep(Duration::from_secs(10));
                let s = server.metrics.summary();
                if s.requests > 0 {
                    println!(
                        "requests={} p50={} p99={} mean_batch={:.1}",
                        s.requests,
                        cheetah::util::fmt_duration(s.p50),
                        cheetah::util::fmt_duration(s.p99),
                        s.mean_batch
                    );
                }
            }
        }
        "serve-secure" => {
            let addr = arg("--addr", "127.0.0.1:7879");
            let model = arg("--model", "netA");
            let pool_depth: usize = arg("--pool-depth", "2").parse()?;
            let pool_workers: usize = arg("--pool-workers", "1").parse()?;
            let workers: usize = arg("--workers", "2").parse()?;
            let eps: f64 = arg("--eps", "0.0").parse()?;
            // Compute threads: 0 = default (CHEETAH_THREADS / all cores).
            let threads: usize = arg("--threads", "0").parse()?;
            // Blinding seed: OS entropy unless pinned for reproducibility.
            let seed_arg = arg("--seed", "");
            let seed = if seed_arg.is_empty() { None } else { Some(seed_arg.parse()?) };
            // The C10K front: one event-loop thread over nonblocking
            // sockets instead of one reader thread per connection.
            let reactor = has("--reactor");
            let max_sessions: usize = arg("--max-sessions", "4096").parse()?;
            let drain_timeout_ms: u64 = arg("--drain-timeout-ms", "5000").parse()?;
            // Fault injection: explicit flag wins; otherwise the
            // CHEETAH_FAULT env var (SecureConfig's default) applies.
            let fault_arg = arg("--fault", "");
            let fault = if fault_arg.is_empty() {
                FaultSpec::from_env()
            } else {
                Some(
                    FaultSpec::parse(&fault_arg)
                        .ok_or_else(|| format!("malformed --fault spec `{fault_arg}`"))?,
                )
            };
            if let Some(spec) = &fault {
                println!("fault injection ON: {spec:?}");
            }
            let net = model_or_fallback(&model);
            let name = net.name.clone();
            let ctx = Arc::new(Context::new(resolve_params(&net)?));
            let cfg = SecureConfig {
                epsilon: eps,
                seed,
                workers,
                pool: PoolConfig { depth: pool_depth, workers: pool_workers },
                threads,
                reactor,
                max_sessions,
                drain_timeout: Duration::from_millis(drain_timeout_ms),
                fault,
                ..SecureConfig::default()
            };
            let server =
                SecureServer::serve(ctx, net, ScalePlan::default_plan(), &addr, cfg)?;
            // Optional live introspection endpoint: serves the obs snapshot
            // as JSON over HTTP/1.0 (curl-able; scraped by serve_bench).
            let stats_addr = arg("--stats-addr", "");
            let _stats = if stats_addr.is_empty() {
                None
            } else {
                let s = cheetah::obs::StatsServer::serve(&stats_addr)?;
                println!("telemetry snapshot endpoint on http://{}/", s.addr);
                Some(s)
            };
            // cfg.threads is scoped to this server's workers; 0 means the
            // process default.
            let effective_threads =
                if threads > 0 { threads } else { cheetah::par::threads() };
            let front = if reactor { "reactor" } else { "threads" };
            println!(
                "secure CHEETAH serving of {name} on {} ({front} front, ε={eps}, \
                 {workers} workers, {effective_threads} compute threads, \
                 pool depth {pool_depth}×{pool_workers}) — Ctrl-C to stop",
                server.addr,
            );
            loop {
                std::thread::sleep(Duration::from_secs(10));
                let s = server.metrics.summary();
                let p = server.pool_stats();
                if s.requests > 0 || p.produced > 0 {
                    println!(
                        "secure queries={} p50={} p99={} sessions={} pool(built={} hits={} inline={})",
                        s.requests,
                        cheetah::util::fmt_duration(s.p50),
                        cheetah::util::fmt_duration(s.p99),
                        server.session_count(),
                        p.produced,
                        p.pool_hits,
                        p.inline_builds
                    );
                }
            }
        }
        "infer" => {
            let model = arg("--model", "netA");
            let eps: f64 = arg("--eps", "0.1").parse()?;
            let label: usize = arg("--label", "3").parse()?;
            let seed: u64 = arg("--seed", "1").parse()?;
            let threads: usize = arg("--threads", "0").parse()?;
            cheetah::par::set_threads(threads);
            let backend_arg = arg("--backend", "cheetah");

            let backends: Vec<Backend> = if backend_arg == "all" {
                Backend::all().to_vec()
            } else {
                backend_arg
                    .split(',')
                    .map(|k| {
                        Backend::from_key(k.trim())
                            .ok_or_else(|| format!("unknown backend `{k}` (try `all`)"))
                    })
                    .collect::<Result<_, _>>()?
            };

            let net = model_or_fallback(&model);
            let ctx = Arc::new(Context::new(resolve_params(&net)?));
            let sample = SyntheticDigits::new(28, 5).render(label);
            println!(
                "one private digit ('{label}') through {} backend(s) on {} \
                 ({} compute threads)",
                backends.len(),
                net.name,
                cheetah::par::threads(),
            );

            let mut reports = Vec::new();
            for backend in backends {
                let mut engine = EngineBuilder::new(backend)
                    .network(net.clone())
                    .context(ctx.clone())
                    .epsilon(eps)
                    .seed(seed)
                    .build()?;
                let prepared = engine.prepare()?;
                let rep = engine.infer(&sample.image)?;
                println!(
                    "  {:>20}: prediction {} (offline {} / {})",
                    backend.name(),
                    rep.argmax,
                    cheetah::util::fmt_duration(prepared.offline_time),
                    cheetah::util::fmt_bytes(prepared.offline_bytes),
                );
                reports.push(rep);
            }
            println!(
                "{}",
                comparison_table(
                    &format!("true label {label} — same input, every backend"),
                    &reports
                )
            );
            Ok(())
        }
        "plan" => {
            // Static parameter planning: no keys, no ciphertexts — just the
            // per-step noise/magnitude budget and the cheapest ladder rung
            // that clears it (or a typed infeasibility).
            let which = arg("--network", "all");
            let archs: Vec<NetworkArch> = if which == "all" {
                NetworkArch::all().to_vec()
            } else {
                vec![NetworkArch::from_key(&which)
                    .ok_or_else(|| format!("unknown network `{which}` (try `all`)"))?]
            };
            let mut infeasible = false;
            for arch in archs {
                let scale = plan_scale(arch);
                let net = Network::build_scaled(arch, 11, scale);
                let note = if scale < 1.0 { format!(" (scale {scale})") } else { String::new() };
                println!("── {}{note} ──", net.name);
                match Plan::for_network(&net) {
                    Ok(plan) => println!("{}", plan.render()),
                    Err(e) => {
                        infeasible = true;
                        println!("no feasible rung: {e}");
                    }
                }
            }
            if infeasible {
                return Err("at least one network has no feasible parameter rung".into());
            }
            Ok(())
        }
        "tables" => {
            cheetah::complexity::print_table1();
            cheetah::complexity::print_table2(
                cheetah::complexity::ConvShape { c_i: 1, c_o: 5, r: 5, hw: 28 * 28, n: 4096 },
                cheetah::complexity::FcShape { n_i: 2048, n_o: 1, n: 4096 },
            );
            Ok(())
        }
        _ => {
            println!(
                "cheetah — privacy-preserved NN inference (paper reproduction)\n\n\
                 subcommands: serve | serve-secure | infer | plan | tables\n\n\
                 paper artifacts → bench targets:\n\
                 \x20 Table 1/2  cargo bench --bench complexity_tables\n\
                 \x20 Table 3    cargo bench --bench conv_bench   (--sweep → Fig. 5)\n\
                 \x20 Table 4/5  cargo bench --bench fc_bench\n\
                 \x20 Table 6    cargo bench --bench relu_bench   (--sweep → Fig. 6, --vgg-relu → §5.1)\n\
                 \x20 Fig. 7     cargo bench --bench accuracy_bench\n\
                 \x20 Table 7    cargo bench --bench e2e_bench    (--breakdown → Fig. 8)\n\
                 \x20 §2.3 ratio cargo bench --bench microops_bench\n\
                 \x20 serving    cargo bench --bench serve_bench  (secure TCP throughput/latency)"
            );
            Ok(())
        }
    }
}
