//! `cheetah` — the leader CLI.
//!
//! ```text
//! cheetah serve         [--addr A] [--model netA] [--max-batch N]     serve a trained model over TCP (plaintext scoring)
//! cheetah serve-secure  [--addr A] [--model netA] [--pool-depth N]    serve the CHEETAH protocol over TCP (private inference)
//!                       [--pool-workers N] [--workers N] [--eps E]
//!                       [--seed S]  (blinding seed; default: OS entropy)
//! cheetah infer         [--model netA] [--eps E] [--label D]          one private inference, verbose report
//! cheetah tables                                                      print the paper's analytic tables
//! cheetah bench-help                                                  how to regenerate every paper table/figure
//! ```

use cheetah::coordinator::{BatchPolicy, Server};
use cheetah::fixed::ScalePlan;
use cheetah::nn::{Network, NetworkArch, SyntheticDigits};
use cheetah::phe::{Context, Params};
use cheetah::protocol::cheetah::CheetahRunner;
use cheetah::runtime::load_trained_network;
use cheetah::serve::{self, PoolConfig, SecureConfig, SecureServer};
use std::time::Duration;

fn arg(flag: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

/// Trained weights when `make artifacts` ran, otherwise a seeded random
/// network of the same architecture (still exercises the full protocol).
fn model_or_fallback(model: &str) -> Network {
    load_trained_network("artifacts", model).unwrap_or_else(|e| {
        eprintln!("artifacts unavailable ({e}); serving an untrained {model}");
        let arch = if model == "netB" { NetworkArch::NetB } else { NetworkArch::NetA };
        Network::build(arch, 11)
    })
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cmd = std::env::args().nth(1).unwrap_or_else(|| "bench-help".into());
    match cmd.as_str() {
        "serve" => {
            let addr = arg("--addr", "127.0.0.1:7878");
            let model = arg("--model", "netA");
            let max_batch: usize = arg("--max-batch", "16").parse()?;
            let net = load_trained_network("artifacts", &model)?;
            println!("serving {} on {addr} (max batch {max_batch})", net.name);
            let server = Server::serve(
                net,
                &addr,
                BatchPolicy { max_batch, linger: Duration::from_millis(2) },
            )?;
            println!("listening on {} — Ctrl-C to stop", server.addr);
            loop {
                std::thread::sleep(Duration::from_secs(10));
                let s = server.metrics.summary();
                if s.requests > 0 {
                    println!(
                        "requests={} p50={} p99={} mean_batch={:.1}",
                        s.requests,
                        cheetah::util::fmt_duration(s.p50),
                        cheetah::util::fmt_duration(s.p99),
                        s.mean_batch
                    );
                }
            }
        }
        "serve-secure" => {
            let addr = arg("--addr", "127.0.0.1:7879");
            let model = arg("--model", "netA");
            let pool_depth: usize = arg("--pool-depth", "2").parse()?;
            let pool_workers: usize = arg("--pool-workers", "1").parse()?;
            let workers: usize = arg("--workers", "2").parse()?;
            let eps: f64 = arg("--eps", "0.0").parse()?;
            // Blinding seed: OS entropy unless pinned for reproducibility.
            let seed_arg = arg("--seed", "");
            let seed = if seed_arg.is_empty() { None } else { Some(seed_arg.parse()?) };
            let net = model_or_fallback(&model);
            let name = net.name.clone();
            let ctx = serve::leak_context(Params::default_params());
            let cfg = SecureConfig {
                epsilon: eps,
                seed,
                workers,
                pool: PoolConfig { depth: pool_depth, workers: pool_workers },
                ..SecureConfig::default()
            };
            let server =
                SecureServer::serve(ctx, net, ScalePlan::default_plan(), &addr, cfg)?;
            println!(
                "secure CHEETAH serving of {name} on {} (ε={eps}, {workers} workers, \
                 pool depth {pool_depth}×{pool_workers}) — Ctrl-C to stop",
                server.addr
            );
            loop {
                std::thread::sleep(Duration::from_secs(10));
                let s = server.metrics.summary();
                let p = server.pool_stats();
                if s.requests > 0 || p.produced > 0 {
                    println!(
                        "secure queries={} p50={} p99={} sessions={} pool(built={} hits={} inline={})",
                        s.requests,
                        cheetah::util::fmt_duration(s.p50),
                        cheetah::util::fmt_duration(s.p99),
                        server.session_count(),
                        p.produced,
                        p.pool_hits,
                        p.inline_builds
                    );
                }
            }
        }
        "infer" => {
            let model = arg("--model", "netA");
            let eps: f64 = arg("--eps", "0.1").parse()?;
            let label: usize = arg("--label", "3").parse()?;
            let ctx = Context::new(Params::default_params());
            let net = load_trained_network("artifacts", &model)?;
            let mut runner = CheetahRunner::new(&ctx, net, ScalePlan::default_plan(), eps, 1);
            let off = runner.run_offline();
            let sample = SyntheticDigits::new(28, 5).render(label);
            let rep = runner.infer(&sample.image);
            println!("true label {label} → prediction {}", rep.argmax);
            println!(
                "online {} compute + {} wire | {} online bytes | {} offline bytes",
                cheetah::util::fmt_duration(rep.online_compute()),
                cheetah::util::fmt_duration(rep.wire_time),
                cheetah::util::fmt_bytes(rep.online_bytes()),
                cheetah::util::fmt_bytes(off)
            );
            for s in &rep.steps {
                println!(
                    "  {:>12}: server {:>10} client {:>10} ops(perm/mult/add) {}/{}/{}",
                    s.name,
                    cheetah::util::fmt_duration(s.server_online),
                    cheetah::util::fmt_duration(s.client_time),
                    s.server_ops.perm + s.client_ops.perm,
                    s.server_ops.mult + s.client_ops.mult,
                    s.server_ops.add + s.client_ops.add,
                );
            }
            Ok(())
        }
        "tables" => {
            cheetah::complexity::print_table1();
            cheetah::complexity::print_table2(
                cheetah::complexity::ConvShape { c_i: 1, c_o: 5, r: 5, hw: 28 * 28, n: 4096 },
                cheetah::complexity::FcShape { n_i: 2048, n_o: 1, n: 4096 },
            );
            Ok(())
        }
        _ => {
            println!(
                "cheetah — privacy-preserved NN inference (paper reproduction)\n\n\
                 subcommands: serve | serve-secure | infer | tables\n\n\
                 paper artifacts → bench targets:\n\
                 \x20 Table 1/2  cargo bench --bench complexity_tables\n\
                 \x20 Table 3    cargo bench --bench conv_bench   (--sweep → Fig. 5)\n\
                 \x20 Table 4/5  cargo bench --bench fc_bench\n\
                 \x20 Table 6    cargo bench --bench relu_bench   (--sweep → Fig. 6, --vgg-relu → §5.1)\n\
                 \x20 Fig. 7     cargo bench --bench accuracy_bench\n\
                 \x20 Table 7    cargo bench --bench e2e_bench    (--breakdown → Fig. 8)\n\
                 \x20 §2.3 ratio cargo bench --bench microops_bench\n\
                 \x20 serving    cargo bench --bench serve_bench  (secure TCP throughput/latency)"
            );
            Ok(())
        }
    }
}
