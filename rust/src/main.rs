//! `cheetah` — the leader CLI.
//!
//! ```text
//! cheetah serve  [--addr A] [--model netA] [--max-batch N]   serve a trained model over TCP
//! cheetah infer  [--model netA] [--eps E] [--label D]        one private inference, verbose report
//! cheetah tables                                             print the paper's analytic tables
//! cheetah bench-help                                         how to regenerate every paper table/figure
//! ```

use cheetah::coordinator::{BatchPolicy, Server};
use cheetah::fixed::ScalePlan;
use cheetah::nn::SyntheticDigits;
use cheetah::phe::{Context, Params};
use cheetah::protocol::cheetah::CheetahRunner;
use cheetah::runtime::load_trained_network;
use std::time::Duration;

fn arg(flag: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let cmd = std::env::args().nth(1).unwrap_or_else(|| "bench-help".into());
    match cmd.as_str() {
        "serve" => {
            let addr = arg("--addr", "127.0.0.1:7878");
            let model = arg("--model", "netA");
            let max_batch: usize = arg("--max-batch", "16").parse()?;
            let net = load_trained_network("artifacts", &model)?;
            println!("serving {} on {addr} (max batch {max_batch})", net.name);
            let server = Server::serve(
                net,
                &addr,
                BatchPolicy { max_batch, linger: Duration::from_millis(2) },
            )?;
            println!("listening on {} — Ctrl-C to stop", server.addr);
            loop {
                std::thread::sleep(Duration::from_secs(10));
                let s = server.metrics.summary();
                if s.requests > 0 {
                    println!(
                        "requests={} p50={} p99={} mean_batch={:.1}",
                        s.requests,
                        cheetah::util::fmt_duration(s.p50),
                        cheetah::util::fmt_duration(s.p99),
                        s.mean_batch
                    );
                }
            }
        }
        "infer" => {
            let model = arg("--model", "netA");
            let eps: f64 = arg("--eps", "0.1").parse()?;
            let label: usize = arg("--label", "3").parse()?;
            let ctx = Context::new(Params::default_params());
            let net = load_trained_network("artifacts", &model)?;
            let mut runner = CheetahRunner::new(&ctx, net, ScalePlan::default_plan(), eps, 1);
            let off = runner.run_offline();
            let sample = SyntheticDigits::new(28, 5).render(label);
            let rep = runner.infer(&sample.image);
            println!("true label {label} → prediction {}", rep.argmax);
            println!(
                "online {} compute + {} wire | {} online bytes | {} offline bytes",
                cheetah::util::fmt_duration(rep.online_compute()),
                cheetah::util::fmt_duration(rep.wire_time),
                cheetah::util::fmt_bytes(rep.online_bytes()),
                cheetah::util::fmt_bytes(off)
            );
            for s in &rep.steps {
                println!(
                    "  {:>12}: server {:>10} client {:>10} ops(perm/mult/add) {}/{}/{}",
                    s.name,
                    cheetah::util::fmt_duration(s.server_online),
                    cheetah::util::fmt_duration(s.client_time),
                    s.server_ops.perm + s.client_ops.perm,
                    s.server_ops.mult + s.client_ops.mult,
                    s.server_ops.add + s.client_ops.add,
                );
            }
            Ok(())
        }
        "tables" => {
            cheetah::complexity::print_table1();
            cheetah::complexity::print_table2(
                cheetah::complexity::ConvShape { c_i: 1, c_o: 5, r: 5, hw: 28 * 28, n: 4096 },
                cheetah::complexity::FcShape { n_i: 2048, n_o: 1, n: 4096 },
            );
            Ok(())
        }
        _ => {
            println!(
                "cheetah — privacy-preserved NN inference (paper reproduction)\n\n\
                 subcommands: serve | infer | tables\n\n\
                 paper artifacts → bench targets:\n\
                 \x20 Table 1/2  cargo bench --bench complexity_tables\n\
                 \x20 Table 3    cargo bench --bench conv_bench   (--sweep → Fig. 5)\n\
                 \x20 Table 4/5  cargo bench --bench fc_bench\n\
                 \x20 Table 6    cargo bench --bench relu_bench   (--sweep → Fig. 6, --vgg-relu → §5.1)\n\
                 \x20 Fig. 7     cargo bench --bench accuracy_bench\n\
                 \x20 Table 7    cargo bench --bench e2e_bench    (--breakdown → Fig. 8)\n\
                 \x20 §2.3 ratio cargo bench --bench microops_bench"
            );
            Ok(())
        }
    }
}
