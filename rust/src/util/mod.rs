//! Shared utilities: modular number theory, PRNGs, a seeded property-test
//! helper (the offline crate registry has no `proptest`; see DESIGN.md
//! substitutions table), and small formatting helpers.

pub mod math;
pub mod proptest;
pub mod rng;

/// Format a byte count with a binary-unit suffix (`12.3 KiB`).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in adaptive units (`1.23 ms`, `456 µs`).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn duration_formatting() {
        use std::time::Duration;
        assert_eq!(fmt_duration(Duration::from_nanos(10)), "10 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
    }
}
