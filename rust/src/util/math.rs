//! Modular number theory for the PHE substrate: 64-bit modular arithmetic,
//! Miller–Rabin primality, NTT-prime search, and primitive roots of unity.
//!
//! All moduli used by the library are odd primes below 2^62 so that lazy
//! (`< 2q`) representations still fit `u64` and products fit `u128`.

/// `(a + b) mod m`, assuming `a, b < m < 2^63`.
#[inline(always)]
pub fn add_mod(a: u64, b: u64, m: u64) -> u64 {
    let s = a + b;
    if s >= m {
        s - m
    } else {
        s
    }
}

/// `(a - b) mod m`, assuming `a, b < m`.
#[inline(always)]
pub fn sub_mod(a: u64, b: u64, m: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a + m - b
    }
}

/// `(a * b) mod m` via 128-bit widening.
#[inline(always)]
pub fn mul_mod(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

/// `a^e mod m` (square-and-multiply).
pub fn pow_mod(mut a: u64, mut e: u64, m: u64) -> u64 {
    let mut r: u64 = 1 % m;
    a %= m;
    while e > 0 {
        if e & 1 == 1 {
            r = mul_mod(r, a, m);
        }
        a = mul_mod(a, a, m);
        e >>= 1;
    }
    r
}

/// Modular inverse of `a` mod prime `m` via Fermat's little theorem.
/// Panics if `a == 0 (mod m)`.
pub fn inv_mod(a: u64, m: u64) -> u64 {
    assert!(a % m != 0, "inverse of zero");
    pow_mod(a, m - 2, m)
}

/// Deterministic Miller–Rabin for u64 (the standard 12-witness set is
/// sufficient for all 64-bit integers).
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n % p == 0 {
            return n == p;
        }
    }
    let mut d = n - 1;
    let mut s = 0u32;
    while d & 1 == 0 {
        d >>= 1;
        s += 1;
    }
    'witness: for a in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = pow_mod(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = mul_mod(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Find the largest prime `<= hi` congruent to `1 (mod modulus)`.
/// Used to generate NTT-friendly primes: for ring degree `n` we need
/// `q ≡ 1 (mod 2n)` so a primitive `2n`-th root of unity exists.
pub fn find_ntt_prime_below(hi: u64, modulus: u64) -> u64 {
    // Largest candidate <= hi that is ≡ 1 (mod modulus).
    let mut c = hi - ((hi - 1) % modulus);
    while c > modulus {
        if is_prime(c) {
            return c;
        }
        c -= modulus;
    }
    panic!("no NTT prime found below {hi} for modulus {modulus}");
}

/// Find `count` distinct NTT primes just below `hi`, each ≡ 1 (mod modulus).
pub fn find_ntt_primes_below(hi: u64, modulus: u64, count: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(count);
    let mut top = hi;
    for _ in 0..count {
        let p = find_ntt_prime_below(top, modulus);
        out.push(p);
        top = p - 1;
    }
    out
}

/// Find a generator (primitive root) of the multiplicative group mod prime
/// `p`, by trial over small candidates. `p - 1`'s factorization is obtained
/// by trial division (fine for our ~62-bit primes with smooth-ish cofactors;
/// bounded by 10^6 trial + a possible large prime cofactor).
pub fn primitive_root(p: u64) -> u64 {
    let phi = p - 1;
    let factors = distinct_prime_factors(phi);
    'cand: for g in 2..p {
        for &f in &factors {
            if pow_mod(g, phi / f, p) == 1 {
                continue 'cand;
            }
        }
        return g;
    }
    unreachable!("no primitive root for prime {p}");
}

/// Distinct prime factors of `n` by trial division up to 10^6, plus
/// Miller–Rabin on the cofactor (our moduli are chosen so the cofactor is
/// prime or 1; panics otherwise).
pub fn distinct_prime_factors(mut n: u64) -> Vec<u64> {
    let mut fs = Vec::new();
    let mut d = 2u64;
    while d <= 1_000_000 && d * d <= n {
        if n % d == 0 {
            fs.push(d);
            while n % d == 0 {
                n /= d;
            }
        }
        d += 1;
    }
    if n > 1 {
        assert!(is_prime(n), "cofactor {n} not prime; unsupported modulus");
        fs.push(n);
    }
    fs
}

/// A primitive `order`-th root of unity mod prime `p`; requires
/// `order | p - 1`.
pub fn primitive_nth_root(order: u64, p: u64) -> u64 {
    assert_eq!((p - 1) % order, 0, "order must divide p-1");
    let g = primitive_root(p);
    let w = pow_mod(g, (p - 1) / order, p);
    debug_assert_eq!(pow_mod(w, order, p), 1);
    debug_assert_ne!(pow_mod(w, order / 2, p), 1);
    w
}

/// Reverse the low `bits` bits of `x`.
#[inline]
pub fn reverse_bits(x: u64, bits: u32) -> u64 {
    if bits == 0 {
        0
    } else {
        x.reverse_bits() >> (64 - bits)
    }
}

/// Integer `floor(log2(n))`; panics on 0.
#[inline]
pub fn ilog2(n: u64) -> u32 {
    assert!(n > 0);
    63 - n.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_primes() {
        assert!(is_prime(2));
        assert!(is_prime(65537));
        assert!(!is_prime(1));
        assert!(!is_prime(561)); // Carmichael
        assert!(is_prime((1u64 << 61) - 1)); // Mersenne prime
    }

    #[test]
    fn ntt_prime_search() {
        let n = 4096u64;
        let q = find_ntt_prime_below(1 << 45, 2 * n);
        assert!(is_prime(q));
        assert_eq!(q % (2 * n), 1);
        let ps = find_ntt_primes_below(1 << 45, 2 * n, 3);
        assert_eq!(ps.len(), 3);
        assert!(ps[0] > ps[1] && ps[1] > ps[2]);
    }

    #[test]
    fn roots_of_unity() {
        let n = 1024u64;
        let q = find_ntt_prime_below(1 << 30, 2 * n);
        let w = primitive_nth_root(2 * n, q);
        assert_eq!(pow_mod(w, 2 * n, q), 1);
        assert_eq!(pow_mod(w, n, q), q - 1); // w^n = -1 (negacyclic)
    }

    #[test]
    fn modular_ops() {
        let m = 1_000_000_007u64;
        assert_eq!(add_mod(m - 1, 5, m), 4);
        assert_eq!(sub_mod(3, 8, m), m - 5);
        assert_eq!(mul_mod(m - 1, m - 1, m), 1);
        for a in [1u64, 2, 12345, m - 2] {
            assert_eq!(mul_mod(a, inv_mod(a, m), m), 1);
        }
    }

    #[test]
    fn bit_reversal() {
        assert_eq!(reverse_bits(0b001, 3), 0b100);
        assert_eq!(reverse_bits(0b110, 3), 0b011);
        for x in 0..64u64 {
            assert_eq!(reverse_bits(reverse_bits(x, 6), 6), x);
        }
    }
}
