//! Minimal seeded property-testing helper.
//!
//! The offline crate registry has no `proptest`, so this module provides the
//! same methodology in miniature: run a predicate over `cases` randomized
//! inputs drawn from a seeded generator; on failure, report the case index
//! and seed so the exact failing input can be replayed deterministically.

use super::rng::SplitMix64;

/// Run `f` on `cases` randomized inputs produced by `gen`. Panics with the
/// replay seed on the first failure (returning `Err(msg)`).
pub fn check<T, G, F>(seed: u64, cases: usize, mut gen: G, mut f: F)
where
    G: FnMut(&mut SplitMix64) -> T,
    F: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        // Derive a per-case stream so failures replay independently.
        let mut rng = SplitMix64::new(seed.wrapping_add(case as u64).wrapping_mul(0x9e37_79b9));
        let input = gen(&mut rng);
        if let Err(msg) = f(&input) {
            panic!(
                "property failed at case {case} (replay seed {seed}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Like [`check`] but the property receives the RNG too (for generating
/// auxiliary randomness inside the property body).
pub fn check_with_rng<F>(seed: u64, cases: usize, mut f: F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = SplitMix64::new(seed.wrapping_add(case as u64).wrapping_mul(0x9e37_79b9));
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at case {case} (replay seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 50, |rng| rng.gen_range(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(1, 50, |rng| rng.gen_range(100), |&x| {
            if x < 10 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }
}
