//! Pseudo-random number generation.
//!
//! Two generators:
//!
//! * [`ChaCha20Rng`] — the ChaCha20 stream cipher as a CSPRNG, used for all
//!   cryptographic material (secret keys, encryption randomness, blinding
//!   factors, garbled-circuit labels). Implemented from the RFC 8439
//!   specification; self-tested against the RFC test vector.
//! * [`SplitMix64`] — a tiny statistical PRNG for test-case generation and
//!   benchmark workloads (never for secrets).
//!
//! The offline crate registry provides no `rand` crate; these are
//! self-contained (see DESIGN.md substitutions table).

/// ChaCha20-based cryptographically secure PRNG (RFC 8439 block function in
/// counter mode over a zero plaintext).
pub struct ChaCha20Rng {
    state: [u32; 16],
    buf: [u8; 64],
    pos: usize,
}

impl ChaCha20Rng {
    /// Construct from a 32-byte seed and a 12-byte nonce (stream id).
    pub fn new(seed: &[u8; 32], stream: u64) -> Self {
        let mut state = [0u32; 16];
        // "expand 32-byte k"
        state[0] = 0x6170_7865;
        state[1] = 0x3320_646e;
        state[2] = 0x7962_2d32;
        state[3] = 0x6b20_6574;
        for i in 0..8 {
            state[4 + i] = u32::from_le_bytes(seed[4 * i..4 * i + 4].try_into().unwrap());
        }
        state[12] = 0; // block counter
        state[13] = 0;
        state[14] = stream as u32;
        state[15] = (stream >> 32) as u32;
        let mut rng = Self { state, buf: [0u8; 64], pos: 64 };
        rng.refill();
        rng.pos = 0;
        rng
    }

    /// The canonical u64-seed → 32-byte-key expansion shared by every
    /// seeded-stream consumer in the crate. The per-query stream-isolation
    /// scheme (`protocol::cheetah::client`, `protocol::gazelle`) relies on
    /// stream 0 of this key being exactly [`ChaCha20Rng::from_u64_seed`],
    /// so there must be one expansion, here.
    pub fn key_from_u64(seed: u64) -> [u8; 32] {
        let mut s = [0u8; 32];
        s[..8].copy_from_slice(&seed.to_le_bytes());
        s[8..16].copy_from_slice(&seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).to_le_bytes());
        s
    }

    /// Convenience: derive from a u64 seed (non-secret contexts like
    /// deterministic tests that still want the crypto generator).
    /// Equivalent to `new(&key_from_u64(seed), 0)`.
    pub fn from_u64_seed(seed: u64) -> Self {
        Self::new(&Self::key_from_u64(seed), 0)
    }

    /// Fresh generator from OS entropy (`/dev/urandom`).
    pub fn from_os_entropy() -> Self {
        use std::io::Read;
        let mut seed = [0u8; 32];
        let mut f = std::fs::File::open("/dev/urandom").expect("open /dev/urandom");
        f.read_exact(&mut seed).expect("read entropy");
        Self::new(&seed, 0)
    }

    #[inline(always)]
    fn quarter(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = (s[d] ^ s[a]).rotate_left(16);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = (s[b] ^ s[c]).rotate_left(12);
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = (s[d] ^ s[a]).rotate_left(8);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = (s[b] ^ s[c]).rotate_left(7);
    }

    fn refill(&mut self) {
        let mut w = self.state;
        for _ in 0..10 {
            // column rounds
            Self::quarter(&mut w, 0, 4, 8, 12);
            Self::quarter(&mut w, 1, 5, 9, 13);
            Self::quarter(&mut w, 2, 6, 10, 14);
            Self::quarter(&mut w, 3, 7, 11, 15);
            // diagonal rounds
            Self::quarter(&mut w, 0, 5, 10, 15);
            Self::quarter(&mut w, 1, 6, 11, 12);
            Self::quarter(&mut w, 2, 7, 8, 13);
            Self::quarter(&mut w, 3, 4, 9, 14);
        }
        for i in 0..16 {
            let v = w[i].wrapping_add(self.state[i]);
            self.buf[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
        }
        // 64-bit counter across words 12..13
        let ctr = (self.state[12] as u64 | ((self.state[13] as u64) << 32)).wrapping_add(1);
        self.state[12] = ctr as u32;
        self.state[13] = (ctr >> 32) as u32;
        self.pos = 0;
    }

    /// Fill `out` with random bytes.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut i = 0;
        while i < out.len() {
            if self.pos == 64 {
                self.refill();
            }
            let take = (out.len() - i).min(64 - self.pos);
            out[i..i + take].copy_from_slice(&self.buf[self.pos..self.pos + take]);
            self.pos += take;
            i += take;
        }
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut b = [0u8; 8];
        self.fill_bytes(&mut b);
        u64::from_le_bytes(b)
    }

    /// Uniform value in `[0, bound)` by rejection sampling (unbiased).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Centered binomial sample with parameter `eta` (sum of `eta` coin
    /// differences); variance `eta/2`. Used as the BFV error distribution —
    /// `eta = 21` gives σ ≈ 3.24, matching SEAL's default σ = 3.2.
    pub fn sample_cbd(&mut self, eta: u32) -> i64 {
        let mut acc = 0i64;
        let mut remaining = eta;
        while remaining > 0 {
            let take = remaining.min(32);
            let bits = self.next_u64();
            let a = (bits as u32 & ((1u64 << take) - 1) as u32).count_ones() as i64;
            let b = ((bits >> 32) as u32 & ((1u64 << take) - 1) as u32).count_ones() as i64;
            acc += a - b;
            remaining -= take;
        }
        acc
    }

    /// Uniform ternary sample in {-1, 0, 1} (the BFV secret distribution).
    pub fn sample_ternary(&mut self) -> i64 {
        self.gen_range(3) as i64 - 1
    }
}

/// SplitMix64 — tiny, fast statistical PRNG for tests and workloads.
#[derive(Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` (modulo bias negligible for bound << 2^64).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        self.next_u64() % bound
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn gen_i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.gen_range((hi - lo + 1) as u64) as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RFC 8439 §2.3.2 test vector: key 00..1f, nonce 00 00 00 09 00 00 00 4a
    /// 00 00 00 00, counter 1 — first block keystream.
    #[test]
    fn chacha20_rfc8439_vector() {
        let mut key = [0u8; 32];
        for (i, k) in key.iter_mut().enumerate() {
            *k = i as u8;
        }
        let mut rng = ChaCha20Rng::new(&key, 0);
        // Override nonce/counter to the RFC vector layout.
        rng.state[12] = 1;
        rng.state[13] = 0x0900_0000;
        rng.state[14] = 0x4a00_0000;
        rng.state[15] = 0x0000_0000;
        rng.refill();
        let expect: [u8; 16] = [
            0x10, 0xf1, 0xe7, 0xe4, 0xd1, 0x3b, 0x59, 0x15, 0x50, 0x0f, 0xdd, 0x1f, 0xa3, 0x20,
            0x71, 0xc4,
        ];
        assert_eq!(&rng.buf[..16], &expect);
    }

    #[test]
    fn cbd_statistics() {
        let mut rng = ChaCha20Rng::from_u64_seed(7);
        let n = 20_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = rng.sample_cbd(21) as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 10.5).abs() < 0.6, "var {var}"); // eta/2 = 10.5
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = ChaCha20Rng::from_u64_seed(1);
        for bound in [1u64, 2, 3, 17, 1 << 40] {
            for _ in 0..100 {
                assert!(rng.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = SplitMix64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn ternary_support() {
        let mut rng = ChaCha20Rng::from_u64_seed(3);
        let mut seen = [false; 3];
        for _ in 0..100 {
            let t = rng.sample_ternary();
            assert!((-1..=1).contains(&t));
            seen[(t + 1) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
