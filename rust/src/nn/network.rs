//! The benchmark network zoo and whole-network inference.
//!
//! Architectures match the paper's §5.2 benchmarks:
//!
//! * **Network A** (DeepSecure [24]): 1 Conv + 2 FC, ReLU — MNIST-scale.
//! * **Network B** (MiniONN [23]): 2 Conv + 2 FC, ReLU + mean pooling.
//! * **AlexNet** [5]: 5 Conv + 3 FC (224×224×3 input).
//! * **VGG-16** [6]: 13 Conv + 3 FC (224×224×3 input).
//! * **NetRes**: a CI-scale residual net — conv stem + 10 identity-skip
//!   blocks (conv + ReLU + residual add) + FC head. The additive skip
//!   chain grows the worst-case activation range linearly with depth,
//!   which is exactly what forces the parameter planner
//!   ([`crate::plan`]) onto a wider plaintext modulus.
//! * **NetPool**: NetB-scale conv net with a *leading* standalone mean
//!   pool, exercising the zero-ciphertext `AvgPool` protocol step.
//!
//! Plus `scaled(f)` variants that shrink spatial dimensions for fast CI
//! benchmarking while preserving layer structure.

use super::layers::{
    forward_layer, forward_linear_quantized, mean_pool_quantized, relu_requantize, Layer,
    LayerKind,
};
use super::tensor::Tensor;
use crate::fixed::ScalePlan;
use crate::util::rng::SplitMix64;

/// Named benchmark architectures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetworkArch {
    /// Network A (DeepSecure): 1 Conv + 2 FC, MNIST-scale.
    NetA,
    /// Network B (MiniONN): 2 Conv + 2 FC with fused mean pools.
    NetB,
    /// AlexNet: 5 Conv + 3 FC, 224×224×3 input.
    AlexNet,
    /// VGG-16: 13 Conv + 3 FC, 224×224×3 input.
    Vgg16,
    /// Residual net: conv stem + 10 identity-skip blocks + FC head.
    NetRes,
    /// NetB-scale conv net with a leading standalone mean pool.
    NetPool,
}

impl NetworkArch {
    /// Human-readable architecture name (used in reports and tables).
    pub fn name(&self) -> &'static str {
        match self {
            NetworkArch::NetA => "Network A",
            NetworkArch::NetB => "Network B",
            NetworkArch::AlexNet => "AlexNet",
            NetworkArch::Vgg16 => "VGG-16",
            NetworkArch::NetRes => "NetRes",
            NetworkArch::NetPool => "NetPool",
        }
    }

    /// Every architecture in the zoo.
    pub fn all() -> [NetworkArch; 6] {
        [
            NetworkArch::NetA,
            NetworkArch::NetB,
            NetworkArch::AlexNet,
            NetworkArch::Vgg16,
            NetworkArch::NetRes,
            NetworkArch::NetPool,
        ]
    }

    /// Short CLI/artifact key, matching `python/compile/model.py::ARCHS`
    /// and the `<key>_weights.bin` artifact names.
    pub fn key(&self) -> &'static str {
        match self {
            NetworkArch::NetA => "netA",
            NetworkArch::NetB => "netB",
            NetworkArch::AlexNet => "alexnet",
            NetworkArch::Vgg16 => "vgg16",
            NetworkArch::NetRes => "netRes",
            NetworkArch::NetPool => "netPool",
        }
    }

    /// Parse a key produced by [`NetworkArch::key`] (CLI flags, artifact
    /// manifests). The single source of architecture definitions is
    /// [`Network::build`]; the trained-weight loader resolves through here
    /// so the two can never drift.
    pub fn from_key(key: &str) -> Option<NetworkArch> {
        match key {
            "netA" | "neta" => Some(NetworkArch::NetA),
            "netB" | "netb" => Some(NetworkArch::NetB),
            "alexnet" => Some(NetworkArch::AlexNet),
            "vgg16" | "vgg" => Some(NetworkArch::Vgg16),
            "netRes" | "netres" => Some(NetworkArch::NetRes),
            "netPool" | "netpool" => Some(NetworkArch::NetPool),
            _ => None,
        }
    }
}

/// A network: input shape + layer stack (with weights).
#[derive(Clone, Debug)]
pub struct Network {
    /// Display name (architecture name, plus a scaled marker).
    pub name: String,
    /// Input shape `(channels, height, width)`.
    pub input_shape: (usize, usize, usize),
    /// The layer stack, input to output.
    pub layers: Vec<Layer>,
}

impl Network {
    /// Build a named architecture with seeded random weights.
    pub fn build(arch: NetworkArch, seed: u64) -> Self {
        Self::build_scaled(arch, seed, 1.0)
    }

    /// Build with spatial dimensions scaled by `f` (0 < f ≤ 1). Channel
    /// counts ≥ 1 are preserved in ratio; layer structure is identical.
    pub fn build_scaled(arch: NetworkArch, seed: u64, f: f64) -> Self {
        let s = |v: usize| ((v as f64 * f).round() as usize).max(1);
        let (input_shape, layers) = match arch {
            NetworkArch::NetA => (
                (1, s(28), s(28)),
                vec![
                    Layer::conv(5, 5, 2, 2),
                    Layer::relu(),
                    Layer::fc(s(100)),
                    Layer::relu(),
                    Layer::fc(10),
                ],
            ),
            NetworkArch::NetB => (
                (1, s(28), s(28)),
                vec![
                    Layer::conv(16, 5, 1, 2),
                    Layer::relu(),
                    Layer::mean_pool(2),
                    Layer::conv(16, 5, 1, 2),
                    Layer::relu(),
                    Layer::mean_pool(2),
                    Layer::fc(s(100)),
                    Layer::relu(),
                    Layer::fc(10),
                ],
            ),
            NetworkArch::AlexNet => (
                (3, s(224), s(224)),
                vec![
                    Layer::conv(s(96), 11, 4, 2),
                    Layer::relu(),
                    Layer::mean_pool(2),
                    Layer::conv(s(256), 5, 1, 2),
                    Layer::relu(),
                    Layer::mean_pool(2),
                    Layer::conv(s(384), 3, 1, 1),
                    Layer::relu(),
                    Layer::conv(s(384), 3, 1, 1),
                    Layer::relu(),
                    Layer::conv(s(256), 3, 1, 1),
                    Layer::relu(),
                    Layer::mean_pool(2),
                    Layer::fc(s(4096)),
                    Layer::relu(),
                    Layer::fc(s(4096)),
                    Layer::relu(),
                    Layer::fc(1000.min(s(1000).max(10))),
                ],
            ),
            NetworkArch::Vgg16 => {
                let mut ls = Vec::new();
                let blocks: [(usize, usize); 5] =
                    [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
                for (ch, reps) in blocks {
                    for _ in 0..reps {
                        ls.push(Layer::conv(s(ch), 3, 1, 1));
                        ls.push(Layer::relu());
                    }
                    ls.push(Layer::mean_pool(2));
                }
                ls.push(Layer::fc(s(4096)));
                ls.push(Layer::relu());
                ls.push(Layer::fc(s(4096)));
                ls.push(Layer::relu());
                ls.push(Layer::fc(1000.min(s(1000).max(10))));
                ((3, s(224), s(224)), ls)
            }
            NetworkArch::NetRes => {
                // Stem, then 10 shape-preserving residual blocks. Each block
                // adds the block input back after the ReLU, so the
                // worst-case activation bound grows by x_max per block —
                // the planner must widen the plaintext modulus for this net.
                let mut ls = vec![Layer::conv(4, 3, 1, 1), Layer::relu()];
                for _ in 0..10 {
                    ls.push(Layer::conv(4, 3, 1, 1));
                    ls.push(Layer::relu());
                    ls.push(Layer::residual_add());
                }
                ls.push(Layer::fc(10));
                ((1, s(12), s(12)), ls)
            }
            NetworkArch::NetPool => (
                (1, s(28), s(28)),
                vec![
                    Layer::mean_pool(2),
                    Layer::conv(8, 5, 1, 2),
                    Layer::relu(),
                    Layer::fc(10),
                ],
            ),
        };
        let mut net = Self {
            name: format!("{}{}", arch.name(), if f < 1.0 { " (scaled)" } else { "" }),
            input_shape,
            layers,
        };
        net.init_weights(seed);
        net
    }

    /// (Re-)initialize every layer's weights from a seed.
    pub fn init_weights(&mut self, seed: u64) {
        let mut rng = SplitMix64::new(seed);
        let (mut c, mut h, mut w) = self.input_shape;
        for layer in self.layers.iter_mut() {
            layer.init_weights(c, h, w, &mut rng);
            let (nc, nh, nw) = layer.out_shape(c, h, w);
            c = nc;
            h = nh;
            w = nw;
        }
    }

    /// Per-layer output shapes.
    pub fn shapes(&self) -> Vec<(usize, usize, usize)> {
        let mut shapes = vec![self.input_shape];
        let (mut c, mut h, mut w) = self.input_shape;
        for layer in &self.layers {
            let s = layer.out_shape(c, h, w);
            shapes.push(s);
            (c, h, w) = s;
        }
        shapes
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        let mut total = 0;
        let (mut c, mut h, mut w) = self.input_shape;
        for layer in &self.layers {
            total += layer.num_weights(c, h, w);
            (c, h, w) = layer.out_shape(c, h, w);
        }
        total
    }

    /// Float reference inference.
    pub fn forward(&self, input: &Tensor) -> Tensor {
        assert_eq!(input.shape(), self.input_shape, "input shape mismatch");
        let mut x = input.clone();
        let mut skip: Option<Tensor> = None;
        for layer in &self.layers {
            match layer.kind {
                LayerKind::ResidualAdd => {
                    let s = skip.take().expect("ResidualAdd without a preceding linear layer");
                    assert_eq!(x.shape(), s.shape(), "residual add needs matching shapes");
                    for (a, b) in x.data.iter_mut().zip(s.data.iter()) {
                        *a += b;
                    }
                }
                _ => {
                    if matches!(layer.kind, LayerKind::Conv2d { .. } | LayerKind::Fc { .. }) {
                        skip = Some(x.clone());
                    }
                    x = forward_layer(layer, &x);
                }
            }
        }
        x
    }

    /// Quantized inference with the paper's per-linear-output noise
    /// `δ ~ U[-ε, ε]` — the plaintext mirror of the private protocol.
    /// Returns logits at activation scale `plan.x`.
    pub fn forward_quantized(
        &self,
        input: &Tensor,
        plan: &ScalePlan,
        epsilon: f64,
        noise_seed: u64,
    ) -> Vec<i64> {
        let mut rng = SplitMix64::new(noise_seed);
        let mut q: Vec<i64> = input.data.iter().map(|&v| plan.quant_x(v)).collect();
        let mut shape = self.input_shape;
        let mut i = 0;
        while i < self.layers.len() {
            let layer = &self.layers[i];
            match layer.kind {
                LayerKind::Conv2d { .. } | LayerKind::Fc { .. } => {
                    let skip_q = q.clone();
                    let (sums, new_shape) =
                        forward_linear_quantized(layer, &q, shape, plan, epsilon, &mut rng);
                    shape = new_shape;
                    // Fused linear + ReLU (the protocol always computes them
                    // jointly); a bare linear at the end stays raw sums
                    // requantized.
                    if i + 1 < self.layers.len()
                        && self.layers[i + 1].kind == LayerKind::Relu
                    {
                        q = relu_requantize(&sums, plan);
                        i += 2;
                        // Identity skip: both protocol parties add their
                        // saved input shares locally, which reconstructs to
                        // this plain integer add at scale `plan.x`.
                        if i < self.layers.len()
                            && self.layers[i].kind == LayerKind::ResidualAdd
                        {
                            debug_assert_eq!(q.len(), skip_q.len());
                            for (a, &s) in q.iter_mut().zip(skip_q.iter()) {
                                *a += s;
                            }
                            i += 1;
                        }
                    } else {
                        let sum_scale = plan.x.mul(plan.k);
                        q = sums
                            .iter()
                            .map(|&s| plan.x.quantize(sum_scale.dequantize(s)))
                            .collect();
                        i += 1;
                    }
                }
                LayerKind::MeanPool { size } => {
                    let (pooled, new_shape) = mean_pool_quantized(&q, shape, size);
                    q = pooled;
                    shape = new_shape;
                    i += 1;
                }
                LayerKind::Relu => {
                    q = q.iter().map(|&v| v.max(0)).collect();
                    i += 1;
                }
                LayerKind::ResidualAdd => {
                    panic!("ResidualAdd must follow a linear+ReLU pair (see ProtocolSpec)")
                }
            }
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_keys_roundtrip() {
        for arch in NetworkArch::all() {
            assert_eq!(NetworkArch::from_key(arch.key()), Some(arch));
        }
        assert_eq!(NetworkArch::from_key("netA"), Some(NetworkArch::NetA));
        assert_eq!(NetworkArch::from_key("mystery"), None);
    }

    #[test]
    fn zoo_shapes() {
        let a = Network::build(NetworkArch::NetA, 1);
        let shapes = a.shapes();
        assert_eq!(shapes[0], (1, 28, 28));
        assert_eq!(*shapes.last().unwrap(), (1, 1, 10));

        let b = Network::build(NetworkArch::NetB, 1);
        assert_eq!(*b.shapes().last().unwrap(), (1, 1, 10));
        assert_eq!(b.layers.len(), 9);
    }

    #[test]
    fn alexnet_vgg_structure() {
        let alex = Network::build_scaled(NetworkArch::AlexNet, 1, 0.25);
        let n_conv = alex
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv2d { .. }))
            .count();
        let n_fc =
            alex.layers.iter().filter(|l| matches!(l.kind, LayerKind::Fc { .. })).count();
        assert_eq!((n_conv, n_fc), (5, 3), "AlexNet is 5 Conv + 3 FC");

        let vgg = Network::build_scaled(NetworkArch::Vgg16, 1, 0.125);
        let n_conv =
            vgg.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv2d { .. })).count();
        let n_fc =
            vgg.layers.iter().filter(|l| matches!(l.kind, LayerKind::Fc { .. })).count();
        assert_eq!((n_conv, n_fc), (13, 3), "VGG-16 is 13 Conv + 3 FC");
    }

    #[test]
    fn full_scale_vgg_dimensions() {
        let vgg = Network::build(NetworkArch::Vgg16, 1);
        let shapes = vgg.shapes();
        // After 5 pool-by-2 stages: 224 → 7; final conv block is 512×7×7.
        let before_fc = shapes[shapes.len() - 6]; // last pool output
        assert_eq!(before_fc, (512, 7, 7));
        assert!(vgg.num_params() > 100_000_000, "VGG-16 has >100M params");
    }

    #[test]
    fn netres_shapes_and_residual_forward() {
        let net = Network::build(NetworkArch::NetRes, 1);
        assert_eq!(net.input_shape, (1, 12, 12));
        let shapes = net.shapes();
        assert_eq!(*shapes.last().unwrap(), (1, 1, 10));
        let n_res = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::ResidualAdd)
            .count();
        assert_eq!(n_res, 10);

        // Residual add really is x + skip: a single block with zero conv
        // weights must reproduce its input exactly.
        let mut tiny = Network {
            name: "tiny-res".into(),
            input_shape: (1, 2, 2),
            layers: vec![Layer::conv(1, 1, 1, 0), Layer::relu(), Layer::residual_add()],
        };
        tiny.layers[0].weights = vec![0.0];
        let input = Tensor::from_vec(vec![1.0, -2.0, 3.0, 4.0], 1, 2, 2);
        let out = tiny.forward(&input);
        assert_eq!(out.data, input.data);
    }

    #[test]
    fn netpool_leading_pool() {
        let net = Network::build(NetworkArch::NetPool, 1);
        assert_eq!(net.layers[0].kind, LayerKind::MeanPool { size: 2 });
        let shapes = net.shapes();
        assert_eq!(shapes[1], (1, 14, 14));
        assert_eq!(*shapes.last().unwrap(), (1, 1, 10));
    }

    #[test]
    fn netres_quantized_deterministic() {
        // The float path never saturates while the quantized path clamps at
        // `x_max`/`y_max`, so a deep residual chain is not argmax-comparable
        // against floats; what must hold is that the quantized mirror (the
        // protocol's ground truth) is well-formed and ε=0 deterministic.
        let plan = ScalePlan::default_plan();
        let net = Network::build(NetworkArch::NetRes, 5);
        let mut rng = SplitMix64::new(17);
        let input = Tensor::from_vec(
            (0..12 * 12).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(),
            1,
            12,
            12,
        );
        let q0 = net.forward_quantized(&input, &plan, 0.0, 7);
        let q1 = net.forward_quantized(&input, &plan, 0.0, 999);
        assert_eq!(q0.len(), 10);
        assert_eq!(q0, q1, "ε=0 must not depend on the noise seed");
        assert!(q0.iter().any(|&v| v != q0[0]), "degenerate logits");
    }

    #[test]
    fn forward_runs_small() {
        let net = Network::build(NetworkArch::NetA, 3);
        let mut rng = SplitMix64::new(9);
        let input = Tensor::from_vec(
            (0..28 * 28).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(),
            1,
            28,
            28,
        );
        let out = net.forward(&input);
        assert_eq!(out.len(), 10);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_close_to_float_and_noise_matters() {
        let plan = ScalePlan::default_plan();
        let net = Network::build(NetworkArch::NetA, 3);
        let mut rng = SplitMix64::new(10);
        let input = Tensor::from_vec(
            (0..28 * 28).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect(),
            1,
            28,
            28,
        );
        let float_out = net.forward(&input);
        let q0 = net.forward_quantized(&input, &plan, 0.0, 7);
        // Same argmax at ε=0 (quantization only).
        let qmax = q0.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        assert_eq!(qmax, float_out.argmax(), "quantization changed the argmax");
        // Large ε perturbs outputs.
        let q_big = net.forward_quantized(&input, &plan, 10.0, 7);
        assert_ne!(q0, q_big);
        // ε=0 is deterministic regardless of the noise seed.
        let q1 = net.forward_quantized(&input, &plan, 0.0, 999);
        assert_eq!(q0, q1);
    }
}
