//! Layers and the plaintext reference forward pass (float and quantized).
//!
//! The quantized path mirrors exactly what the CHEETAH protocol computes:
//! activations and weights quantized per the [`crate::fixed::ScalePlan`],
//! with optional uniform noise `δ ~ U[-ε, ε]` added to every linear output
//! (the paper's Fig. 7 experiment), and activations clamped to the plan's
//! representable range.

use super::tensor::Tensor;
use crate::fixed::ScalePlan;
use crate::par;
use crate::util::rng::SplitMix64;

/// The kind and hyper-parameters of a layer.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    /// 2-D convolution, `weights[o][i][ky][kx]` flattened, stride, zero-pad.
    Conv2d { out_channels: usize, kernel: usize, stride: usize, pad: usize },
    /// ReLU activation.
    Relu,
    /// Mean pooling over `size × size` windows with stride `size`.
    MeanPool { size: usize },
    /// Fully connected: `weights[o][i]` flattened.
    Fc { out_features: usize },
    /// Identity skip connection: adds the input of the preceding linear
    /// layer to the current activation (`x ← x + x_skip`). Shape-preserving
    /// and weight-free; in the private protocol both parties add their
    /// saved shares locally, so it costs zero ciphertext operations.
    ResidualAdd,
}

/// A layer with (possibly empty) weights.
#[derive(Clone, Debug)]
pub struct Layer {
    /// What the layer computes and its hyper-parameters.
    pub kind: LayerKind,
    /// Row-major weights; empty for Relu/MeanPool/ResidualAdd.
    pub weights: Vec<f64>,
}

impl Layer {
    /// 2-D convolution layer (weights are initialized separately).
    pub fn conv(out_channels: usize, kernel: usize, stride: usize, pad: usize) -> Self {
        Self { kind: LayerKind::Conv2d { out_channels, kernel, stride, pad }, weights: vec![] }
    }
    /// ReLU activation layer.
    pub fn relu() -> Self {
        Self { kind: LayerKind::Relu, weights: vec![] }
    }
    /// Mean-pooling layer over `size × size` windows.
    pub fn mean_pool(size: usize) -> Self {
        Self { kind: LayerKind::MeanPool { size }, weights: vec![] }
    }
    /// Fully-connected layer (weights are initialized separately).
    pub fn fc(out_features: usize) -> Self {
        Self { kind: LayerKind::Fc { out_features }, weights: vec![] }
    }
    /// Identity residual add (skip connection back to the preceding linear
    /// layer's input).
    pub fn residual_add() -> Self {
        Self { kind: LayerKind::ResidualAdd, weights: vec![] }
    }

    /// Output shape for a given input shape.
    pub fn out_shape(&self, c: usize, h: usize, w: usize) -> (usize, usize, usize) {
        match self.kind {
            LayerKind::Conv2d { out_channels, kernel, stride, pad } => {
                let oh = (h + 2 * pad - kernel) / stride + 1;
                let ow = (w + 2 * pad - kernel) / stride + 1;
                (out_channels, oh, ow)
            }
            LayerKind::Relu | LayerKind::ResidualAdd => (c, h, w),
            LayerKind::MeanPool { size } => (c, h / size, w / size),
            LayerKind::Fc { out_features } => (1, 1, out_features),
        }
    }

    /// Number of weight parameters for input shape.
    pub fn num_weights(&self, c: usize, h: usize, w: usize) -> usize {
        match self.kind {
            LayerKind::Conv2d { out_channels, kernel, .. } => out_channels * c * kernel * kernel,
            LayerKind::Fc { out_features } => out_features * c * h * w,
            _ => 0,
        }
    }

    /// Initialize weights with scaled uniform values (He-style fan-in
    /// scaling so activations stay in the quantization range).
    pub fn init_weights(&mut self, c: usize, h: usize, w: usize, rng: &mut SplitMix64) {
        let n = self.num_weights(c, h, w);
        let fan_in = match self.kind {
            LayerKind::Conv2d { kernel, .. } => c * kernel * kernel,
            LayerKind::Fc { .. } => c * h * w,
            _ => 1,
        };
        let bound = (2.0 / fan_in as f64).sqrt();
        self.weights = (0..n).map(|_| rng.gen_f64_range(-bound, bound)).collect();
    }

    /// Conv weight accessor: `weights[o][i][ky][kx]`.
    #[inline]
    pub fn conv_w(&self, in_channels: usize, kernel: usize, o: usize, i: usize, ky: usize, kx: usize) -> f64 {
        self.weights[((o * in_channels + i) * kernel + ky) * kernel + kx]
    }

    /// FC weight accessor: `weights[o][i]`.
    #[inline]
    pub fn fc_w(&self, in_features: usize, o: usize, i: usize) -> f64 {
        self.weights[o * in_features + i]
    }
}

/// Float forward pass for one layer. The conv and FC loops fan their
/// independent output channels/neurons across the [`crate::par`] pool
/// (float accumulation order within one output is unchanged, so results
/// are bit-identical at any thread count).
pub fn forward_layer(layer: &Layer, input: &Tensor) -> Tensor {
    match layer.kind {
        LayerKind::Conv2d { out_channels, kernel, stride, pad } => {
            let (oc, oh, ow) = layer.out_shape(input.c, input.h, input.w);
            let mut out = Tensor::zeros(oc, oh, ow);
            debug_assert_eq!(oc, out_channels);
            // Each output channel owns one disjoint oh·ow plane.
            par::for_each_chunk_mut(&mut out.data, oh * ow, |o, plane| {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0;
                        for i in 0..input.c {
                            for ky in 0..kernel {
                                for kx in 0..kernel {
                                    let y = (oy * stride + ky) as isize - pad as isize;
                                    let x = (ox * stride + kx) as isize - pad as isize;
                                    acc += layer.conv_w(input.c, kernel, o, i, ky, kx)
                                        * input.at_padded(i, y, x);
                                }
                            }
                        }
                        plane[oy * ow + ox] = acc;
                    }
                }
            });
            out
        }
        LayerKind::Relu => {
            let mut out = input.clone();
            for v in out.data.iter_mut() {
                *v = v.max(0.0);
            }
            out
        }
        LayerKind::MeanPool { size } => {
            let (oc, oh, ow) = layer.out_shape(input.c, input.h, input.w);
            let mut out = Tensor::zeros(oc, oh, ow);
            let norm = 1.0 / (size * size) as f64;
            for c in 0..oc {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0;
                        for dy in 0..size {
                            for dx in 0..size {
                                acc += input.at(c, oy * size + dy, ox * size + dx);
                            }
                        }
                        *out.at_mut(c, oy, ox) = acc * norm;
                    }
                }
            }
            out
        }
        LayerKind::ResidualAdd => {
            panic!("ResidualAdd needs the saved skip input — handled by Network::forward")
        }
        LayerKind::Fc { out_features } => {
            let in_features = input.len();
            let mut out = Tensor::zeros(1, 1, out_features);
            // Group output neurons so each task amortizes dispatch cost.
            const NEURONS_PER_CHUNK: usize = 16;
            par::for_each_chunk_mut(&mut out.data, NEURONS_PER_CHUNK, |ci, chunk| {
                for (k, slot) in chunk.iter_mut().enumerate() {
                    let o = ci * NEURONS_PER_CHUNK + k;
                    let mut acc = 0.0;
                    for (i, &x) in input.data.iter().enumerate() {
                        acc += layer.fc_w(in_features, o, i) * x;
                    }
                    *slot = acc;
                }
            });
            out
        }
    }
}

/// Quantized forward pass for one linear layer with optional per-output
/// noise δ — the *exact* arithmetic the private protocol performs. Input
/// and output activations are integers at `plan.x`; weights at `plan.k`.
/// Returns pre-activation block sums at scale `plan.x · plan.k`.
pub fn forward_linear_quantized(
    layer: &Layer,
    input_q: &[i64],
    in_shape: (usize, usize, usize),
    plan: &ScalePlan,
    epsilon: f64,
    rng: &mut SplitMix64,
) -> (Vec<i64>, (usize, usize, usize)) {
    let (c, h, w) = in_shape;
    let sum_scale = plan.x.mul(plan.k);
    let at = |ch: usize, y: isize, x: isize| -> i64 {
        if y < 0 || x < 0 || y >= h as isize || x >= w as isize {
            0
        } else {
            input_q[(ch * h + y as usize) * w + x as usize]
        }
    };
    match layer.kind {
        LayerKind::Conv2d { out_channels, kernel, stride, pad } => {
            let (oc, oh, ow) = layer.out_shape(c, h, w);
            let mut out = vec![0i64; oc * oh * ow];
            for o in 0..out_channels {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0i64;
                        for i in 0..c {
                            for ky in 0..kernel {
                                for kx in 0..kernel {
                                    let y = (oy * stride + ky) as isize - pad as isize;
                                    let x = (ox * stride + kx) as isize - pad as isize;
                                    let kq = plan.quant_k(layer.conv_w(c, kernel, o, i, ky, kx));
                                    acc += kq * at(i, y, x);
                                }
                            }
                        }
                        let delta = sum_scale.quantize(rng.gen_f64_range(-epsilon, epsilon));
                        out[(o * oh + oy) * ow + ox] = acc + delta;
                    }
                }
            }
            (out, (oc, oh, ow))
        }
        LayerKind::Fc { out_features } => {
            let in_features = input_q.len();
            let mut out = vec![0i64; out_features];
            for (o, out_slot) in out.iter_mut().enumerate() {
                let mut acc = 0i64;
                for (i, &x) in input_q.iter().enumerate() {
                    acc += plan.quant_k(layer.fc_w(in_features, o, i)) * x;
                }
                let delta = sum_scale.quantize(rng.gen_f64_range(-epsilon, epsilon));
                *out_slot = acc + delta;
            }
            (out, (1, 1, out_features))
        }
        _ => panic!("forward_linear_quantized only handles linear layers"),
    }
}

/// Quantized nonlinear: ReLU on block sums, requantized back to activation
/// scale `plan.x` and clamped — mirrors the protocol's recovery hop (the
/// client re-encodes `y` at `plan.y`, multiplies by `1/v` at `plan.id`).
pub fn relu_requantize(sums: &[i64], plan: &ScalePlan) -> Vec<i64> {
    let sum_scale = plan.x.mul(plan.k);
    sums.iter()
        .map(|&s| {
            let real = sum_scale.dequantize(s.max(0));
            // Two-step requantization identical to the protocol: y at plan.y,
            // multiplied by an exactly-representable 1/v pair ≈ scale plan.id.
            let y = plan.y.quantize(real.clamp(0.0, plan.y_max));
            let back = plan.y.dequantize(y);
            plan.x.quantize(back.min(plan.x_max))
        })
        .collect()
}

/// Quantized mean-pool on activation integers (shares are pooled the same
/// way by each party in the protocol). Truncating division — both parties
/// apply the identical rule.
pub fn mean_pool_quantized(
    input_q: &[i64],
    in_shape: (usize, usize, usize),
    size: usize,
) -> (Vec<i64>, (usize, usize, usize)) {
    let (c, h, w) = in_shape;
    let (oh, ow) = (h / size, w / size);
    let mut out = vec![0i64; c * oh * ow];
    let div = (size * size) as i64;
    for ch in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0i64;
                for dy in 0..size {
                    for dx in 0..size {
                        acc += input_q[(ch * h + oy * size + dy) * w + ox * size + dx];
                    }
                }
                out[(ch * oh + oy) * ow + ox] = acc.div_euclid(div);
            }
        }
    }
    (out, (c, oh, ow))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1×1 kernel with weight 1.0 is the identity.
        let mut layer = Layer::conv(1, 1, 1, 0);
        layer.weights = vec![1.0];
        let input = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], 1, 2, 2);
        let out = forward_layer(&layer, &input);
        assert_eq!(out.data, input.data);
    }

    #[test]
    fn conv_known_values() {
        // 2×2 input, 3×3 kernel, pad 1, stride 1 → the paper's §3.1 example.
        let mut layer = Layer::conv(1, 3, 1, 1);
        layer.weights = (1..=9).map(|v| v as f64).collect(); // k(1,1)..k(3,3)
        let input = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], 1, 2, 2);
        let out = forward_layer(&layer, &input);
        assert_eq!(out.shape(), (1, 2, 2));
        // Con_1 (output at 0,0): k(2,2)x(1,1)+k(2,3)x(1,2)+k(3,2)x(2,1)+k(3,3)x(2,2)
        //                       = 5*1 + 6*2 + 8*3 + 9*4 = 77
        assert_eq!(out.at(0, 0, 0), 77.0);
        // Con_2 (output at 0,1): k(2,1)*1 + k(2,2)*2 + k(3,1)*3 + k(3,2)*4 = 4+10+21+32 = 67
        assert_eq!(out.at(0, 0, 1), 4.0 + 10.0 + 21.0 + 32.0);
    }

    #[test]
    fn conv_stride_shape() {
        let layer = Layer::conv(8, 5, 2, 0);
        assert_eq!(layer.out_shape(1, 28, 28), (8, 12, 12));
    }

    #[test]
    fn relu_zeroes_negatives() {
        let layer = Layer::relu();
        let input = Tensor::from_flat(vec![-1.0, 2.0, -0.5, 0.0]);
        let out = forward_layer(&layer, &input);
        assert_eq!(out.data, vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn mean_pool_averages() {
        let layer = Layer::mean_pool(2);
        let input = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], 1, 2, 2);
        let out = forward_layer(&layer, &input);
        assert_eq!(out.data, vec![2.5]);
    }

    #[test]
    fn fc_dot_products() {
        let mut layer = Layer::fc(2);
        layer.weights = vec![1.0, 0.0, 0.0, /* row 2 */ 0.0, 1.0, 1.0];
        let input = Tensor::from_flat(vec![3.0, 4.0, 5.0]);
        let out = forward_layer(&layer, &input);
        assert_eq!(out.data, vec![3.0, 9.0]);
    }

    #[test]
    fn quantized_conv_matches_float() {
        let plan = ScalePlan::default_plan();
        let mut rng = SplitMix64::new(1);
        let mut layer = Layer::conv(2, 3, 1, 1);
        layer.init_weights(1, 4, 4, &mut rng);
        let input = Tensor::from_vec((0..16).map(|i| (i as f64 - 8.0) / 8.0).collect(), 1, 4, 4);
        let fl = forward_layer(&layer, &input);

        let input_q: Vec<i64> = input.data.iter().map(|&x| plan.quant_x(x)).collect();
        let (sums, shape) = forward_linear_quantized(&layer, &input_q, (1, 4, 4), &plan, 0.0, &mut rng);
        assert_eq!(shape, (2, 4, 4));
        let sum_scale = plan.x.mul(plan.k);
        for i in 0..fl.len() {
            let got = sum_scale.dequantize(sums[i]);
            assert!((got - fl.data[i]).abs() < 0.1, "i={i} got={got} want={}", fl.data[i]);
        }
    }

    #[test]
    fn relu_requantize_behaviour() {
        let plan = ScalePlan::default_plan();
        let sum_scale = plan.x.mul(plan.k);
        let sums = vec![sum_scale.quantize(1.0), sum_scale.quantize(-1.0), 0];
        let act = relu_requantize(&sums, &plan);
        assert_eq!(act[0], plan.x.quantize(1.0));
        assert_eq!(act[1], 0);
        assert_eq!(act[2], 0);
    }

    #[test]
    fn quantized_mean_pool() {
        let (out, shape) = mean_pool_quantized(&[4, 8, 12, 16], (1, 2, 2), 2);
        assert_eq!(out, vec![10]);
        assert_eq!(shape, (1, 1, 1));
    }
}
