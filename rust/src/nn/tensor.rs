//! A minimal channel-major 3-D tensor (`c × h × w`) plus flat views.
//!
//! Deliberately simple: the protocols need explicit index arithmetic (slot
//! packing mirrors these layouts), so a transparent representation beats a
//! clever one.

/// Dense `f64` tensor with shape `(channels, height, width)`.
/// A flat vector is represented as `(1, 1, len)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Channel-major elements: index `(c·h + y)·w + x`.
    pub data: Vec<f64>,
    /// Channel count.
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        Self { data: vec![0.0; c * h * w], c, h, w }
    }

    /// Wrap channel-major data in a shaped tensor (panics on length mismatch).
    pub fn from_vec(data: Vec<f64>, c: usize, h: usize, w: usize) -> Self {
        assert_eq!(data.len(), c * h * w, "shape/data mismatch");
        Self { data, c, h, w }
    }

    /// Flat vector constructor.
    pub fn from_flat(data: Vec<f64>) -> Self {
        let n = data.len();
        Self { data, c: 1, h: 1, w: n }
    }

    /// Total element count (`c·h·w`).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Shape as `(channels, height, width)`.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.c, self.h, self.w)
    }

    /// Read the element at `(c, y, x)`.
    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f64 {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        self.data[(c * self.h + y) * self.w + x]
    }

    /// Mutable access to the element at `(c, y, x)`.
    #[inline]
    pub fn at_mut(&mut self, c: usize, y: usize, x: usize) -> &mut f64 {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        &mut self.data[(c * self.h + y) * self.w + x]
    }

    /// Padded read: zero outside bounds (for "same" convolutions).
    #[inline]
    pub fn at_padded(&self, c: usize, y: isize, x: isize) -> f64 {
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0.0
        } else {
            self.at(c, y as usize, x as usize)
        }
    }

    /// Index of the maximum element (argmax for classification).
    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Largest absolute element value (0 for an empty tensor).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_channel_major() {
        let mut t = Tensor::zeros(2, 3, 4);
        *t.at_mut(1, 2, 3) = 7.0;
        assert_eq!(t.data[(1 * 3 + 2) * 4 + 3], 7.0);
        assert_eq!(t.at(1, 2, 3), 7.0);
        assert_eq!(t.at(0, 0, 0), 0.0);
    }

    #[test]
    fn padded_reads() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], 1, 2, 2);
        assert_eq!(t.at_padded(0, -1, 0), 0.0);
        assert_eq!(t.at_padded(0, 0, 2), 0.0);
        assert_eq!(t.at_padded(0, 1, 1), 4.0);
    }

    #[test]
    fn argmax_and_max_abs() {
        let t = Tensor::from_flat(vec![0.1, -5.0, 3.0, 2.0]);
        assert_eq!(t.argmax(), 2);
        assert_eq!(t.max_abs(), 5.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(vec![1.0; 5], 1, 2, 3);
    }
}
