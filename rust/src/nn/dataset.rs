//! Synthetic-digits dataset — the offline-environment substitute for MNIST
//! (see DESIGN.md substitutions). Ten glyph classes rendered procedurally
//! from a 5×7 segment font, scaled to the target resolution with random
//! sub-pixel shifts, per-sample amplitude jitter and additive noise.
//! Deterministic given a seed; train/test splits use disjoint seeds.

use super::tensor::Tensor;
use crate::util::rng::SplitMix64;

/// 5×7 bitmap font for digits 0–9 (rows top-to-bottom, 5-bit rows).
const FONT: [[u8; 7]; 10] = [
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111], // 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100], // 9
];

/// One labelled sample.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Rendered image, `(channels, size, size)`.
    pub image: Tensor,
    /// Digit class in `0..10`.
    pub label: usize,
}

/// The synthetic-digits generator.
pub struct SyntheticDigits {
    /// Square image side length in pixels.
    pub size: usize,
    rng: SplitMix64,
}

impl SyntheticDigits {
    /// `size`: square image side (e.g. 28). `seed`: determinism handle.
    pub fn new(size: usize, seed: u64) -> Self {
        assert!(size >= 12, "minimum supported image size is 12");
        Self { size, rng: SplitMix64::new(seed) }
    }

    /// Render one sample of class `label` with jitter and noise.
    pub fn render(&mut self, label: usize) -> Sample {
        assert!(label < 10);
        let s = self.size;
        let glyph = &FONT[label];
        // Random placement: the 5×7 glyph scales to ~60% of the image with
        // a random offset of up to ±12% of the image size.
        let scale = s as f64 * 0.6 / 7.0;
        let margin = s as f64 * 0.06;
        let ox = self.rng.gen_f64_range(-margin, margin) + s as f64 * 0.25;
        let oy = self.rng.gen_f64_range(-margin, margin) + s as f64 * 0.15;
        let amp = self.rng.gen_f64_range(0.75, 1.0);
        let noise_lvl = self.rng.gen_f64_range(0.02, 0.08);

        let mut img = Tensor::zeros(1, s, s);
        for y in 0..s {
            for x in 0..s {
                // Map pixel to glyph coordinates (bilinear-ish box sample).
                let gy = (y as f64 - oy) / scale;
                let gx = (x as f64 - ox) / (scale * 5.0 / 7.0 * 1.4);
                let mut v = 0.0;
                if gy >= 0.0 && gy < 7.0 && gx >= 0.0 && gx < 5.0 {
                    let row = glyph[gy as usize];
                    let bit = 4 - gx as usize;
                    if (row >> bit) & 1 == 1 {
                        v = amp;
                    }
                }
                v += self.rng.gen_f64_range(-noise_lvl, noise_lvl);
                *img.at_mut(0, y, x) = v.clamp(0.0, 1.0);
            }
        }
        Sample { image: img, label }
    }

    /// Generate a balanced batch of `count` samples (round-robin labels).
    pub fn batch(&mut self, count: usize) -> Vec<Sample> {
        (0..count).map(|i| self.render(i % 10)).collect()
    }

    /// Render one sample replicated across `channels` input channels —
    /// the calibration corpus for multi-channel (RGB-style) networks like
    /// AlexNet/VGG, where every channel carries the same glyph (activation
    /// equalization only needs representative magnitudes, not color).
    pub fn render_channels(&mut self, label: usize, channels: usize) -> Sample {
        assert!(channels >= 1);
        let base = self.render(label);
        if channels == 1 {
            return base;
        }
        let (_, h, w) = base.image.shape();
        let mut data = Vec::with_capacity(channels * h * w);
        for _ in 0..channels {
            data.extend_from_slice(&base.image.data);
        }
        Sample { image: Tensor::from_vec(data, channels, h, w), label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticDigits::new(28, 5);
        let mut b = SyntheticDigits::new(28, 5);
        let sa = a.render(3);
        let sb = b.render(3);
        assert_eq!(sa.image.data, sb.image.data);
        let mut c = SyntheticDigits::new(28, 6);
        assert_ne!(c.render(3).image.data, sa.image.data);
    }

    #[test]
    fn images_in_range_and_nonempty() {
        let mut g = SyntheticDigits::new(28, 1);
        for s in g.batch(20) {
            assert!(s.image.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
            let ink: f64 = s.image.data.iter().sum();
            assert!(ink > 5.0, "glyph {label} rendered empty", label = s.label);
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Nearest-mean classification must beat chance by a wide margin —
        // sanity that the classes carry signal. Means over 30 renders smear
        // out the per-sample jitter.
        let mut g = SyntheticDigits::new(28, 2);
        let templates: Vec<Tensor> = (0..10)
            .map(|d| {
                let mut acc = Tensor::zeros(1, 28, 28);
                let reps = 30;
                for _ in 0..reps {
                    let img = g.render(d).image;
                    for (a, v) in acc.data.iter_mut().zip(&img.data) {
                        *a += v / reps as f64;
                    }
                }
                acc
            })
            .collect();
        let mut correct = 0;
        let total = 100;
        let mut g2 = SyntheticDigits::new(28, 3);
        for s in g2.batch(total) {
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = templates[a]
                        .data
                        .iter()
                        .zip(&s.image.data)
                        .map(|(t, v)| (t - v) * (t - v))
                        .sum();
                    let db: f64 = templates[b]
                        .data
                        .iter()
                        .zip(&s.image.data)
                        .map(|(t, v)| (t - v) * (t - v))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == s.label {
                correct += 1;
            }
        }
        assert!(correct > 60, "template accuracy only {correct}/{total}");
    }

    #[test]
    fn render_channels_replicates_the_glyph() {
        let mut g = SyntheticDigits::new(12, 8);
        let s = g.render_channels(5, 3);
        assert_eq!(s.image.shape(), (3, 12, 12));
        let hw = 12 * 12;
        assert_eq!(&s.image.data[..hw], &s.image.data[hw..2 * hw]);
        assert_eq!(&s.image.data[..hw], &s.image.data[2 * hw..]);
        // Single-channel request is the plain render.
        let s1 = g.render_channels(5, 1);
        assert_eq!(s1.image.shape(), (1, 12, 12));
    }

    #[test]
    fn batch_is_balanced() {
        let mut g = SyntheticDigits::new(28, 4);
        let batch = g.batch(30);
        for d in 0..10 {
            assert_eq!(batch.iter().filter(|s| s.label == d).count(), 3);
        }
    }
}
