//! Neural-network substrate: tensors, layers, the benchmark network zoo
//! (Network A, Network B, AlexNet, VGG-16, NetRes, NetPool), plaintext
//! reference inference (float and quantized), and the synthetic-digits
//! dataset.
//!
//! The plaintext quantized forward pass is the correctness oracle for the
//! private protocols: CHEETAH must produce the same argmax (and values
//! within quantization + δ-noise tolerance).

pub mod dataset;
pub mod layers;
pub mod network;
pub mod tensor;

pub use dataset::SyntheticDigits;
pub use layers::{Layer, LayerKind};
pub use network::{Network, NetworkArch};
pub use tensor::Tensor;
