//! Print the paper's analytic tables (Table 1 lineage, Table 2 op-count
//! complexity) — the fast, no-crypto companion to the measured benches.
//!
//! Run: `cargo run --release --example paper_tables`

use cheetah::complexity::{print_table1, print_table2, ConvShape, FcShape};

fn main() {
    print_table1();
    // The paper's §3.1 SISO example shape and the Table-4 FC shape.
    print_table2(
        ConvShape { c_i: 1, c_o: 5, r: 5, hw: 28 * 28, n: 4096 },
        FcShape { n_i: 2048, n_o: 1, n: 4096 },
    );
    // A VGG-16-interior shape, showing the gap at practical scale.
    print_table2(
        ConvShape { c_i: 256, c_o: 256, r: 3, hw: 28 * 28, n: 4096 },
        FcShape { n_i: 4096, n_o: 1000, n: 4096 },
    );
}
