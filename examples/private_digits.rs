//! Private digit classification with a *trained* model: loads the weights
//! trained by `make artifacts`, serves them through the full CHEETAH
//! protocol via the unified engine API, and reports accuracy + per-query
//! cost against the plaintext float engine — showing the paper's "no
//! accuracy loss" property on a real (small) workload.
//!
//! Run: `make artifacts && cargo run --release --example private_digits [-- N]`

use cheetah::engine::{Backend, EngineBuilder, InferenceEngine};
use cheetah::nn::SyntheticDigits;
use cheetah::runtime::load_trained_network;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_queries: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(20);

    let net = load_trained_network("artifacts", "netA")?;
    println!("loaded {} ({} params)", net.name, net.num_params());

    let mut private = EngineBuilder::new(Backend::Cheetah)
        .network(net.clone())
        .epsilon(0.1)
        .seed(7)
        .build()?;
    let mut plain = EngineBuilder::new(Backend::PlaintextFloat).network(net).build()?;
    let prepared = private.prepare()?;
    println!(
        "offline phase: {} in {}",
        cheetah::util::fmt_bytes(prepared.offline_bytes),
        cheetah::util::fmt_duration(prepared.offline_time)
    );

    let mut gen = SyntheticDigits::new(28, 4242);
    let mut private_correct = 0;
    let mut plain_correct = 0;
    let mut agree = 0;
    let mut total_online = std::time::Duration::ZERO;
    for s in gen.batch(n_queries) {
        let rep = private.infer(&s.image)?;
        let plain_rep = plain.infer(&s.image)?;
        private_correct += (rep.argmax == s.label) as usize;
        plain_correct += (plain_rep.argmax == s.label) as usize;
        agree += (rep.argmax == plain_rep.argmax) as usize;
        total_online += rep.online_total();
    }
    println!(
        "\n{n_queries} private queries: accuracy {}/{n_queries} (plaintext {}/{n_queries}), \
         private==plaintext on {agree}/{n_queries}",
        private_correct, plain_correct
    );
    println!(
        "mean online latency: {}",
        cheetah::util::fmt_duration(total_online / n_queries as u32)
    );
    // "Negligible accuracy loss" (paper Fig. 7 at ε=0.1): allow isolated
    // δ-noise flips on marginal samples.
    if agree * 6 < n_queries * 5 {
        return Err(
            format!("private inference diverged from plaintext ({agree}/{n_queries})").into()
        );
    }
    Ok(())
}
