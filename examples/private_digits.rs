//! Private digit classification with a *trained* model: loads the weights
//! trained by `make artifacts` (JAX, build-time), serves them through the
//! full CHEETAH protocol, and reports accuracy + per-query cost — showing
//! the paper's "no accuracy loss" property on a real (small) workload.
//!
//! Run: `make artifacts && cargo run --release --example private_digits [-- N]`

use cheetah::fixed::ScalePlan;
use cheetah::nn::SyntheticDigits;
use cheetah::phe::{Context, Params};
use cheetah::protocol::cheetah::CheetahRunner;
use cheetah::runtime::load_trained_network;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_queries: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(20);
    let ctx = Context::new(Params::default_params());
    let plan = ScalePlan::default_plan();

    let net = load_trained_network("artifacts", "netA")?;
    println!("loaded {} ({} params)", net.name, net.num_params());
    let plain = net.clone();

    let mut runner = CheetahRunner::new(&ctx, net, plan, 0.1, 7);
    runner.run_offline();

    let mut gen = SyntheticDigits::new(28, 4242);
    let mut private_correct = 0;
    let mut plain_correct = 0;
    let mut agree = 0;
    let mut total_online = std::time::Duration::ZERO;
    for s in gen.batch(n_queries) {
        let rep = runner.infer(&s.image);
        let plain_pred = plain.forward(&s.image).argmax();
        private_correct += (rep.argmax == s.label) as usize;
        plain_correct += (plain_pred == s.label) as usize;
        agree += (rep.argmax == plain_pred) as usize;
        total_online += rep.online_total();
    }
    println!(
        "\n{n_queries} private queries: accuracy {}/{n_queries} (plaintext {}/{n_queries}), \
         private==plaintext on {agree}/{n_queries}",
        private_correct, plain_correct
    );
    println!(
        "mean online latency: {}",
        cheetah::util::fmt_duration(total_online / n_queries as u32)
    );
    // "Negligible accuracy loss" (paper Fig. 7 at ε=0.1): allow isolated
    // δ-noise flips on marginal samples.
    if agree * 6 < n_queries * 5 {
        return Err(
            format!("private inference diverged from plaintext ({agree}/{n_queries})").into()
        );
    }
    Ok(())
}
