//! Quickstart: private inference on a 2-layer CNN in ~40 lines.
//!
//! The client's digit never leaves its side unencrypted; the server's
//! weights never leave its side at all; and the linear layers use **zero**
//! ciphertext permutations (the paper's contribution).
//!
//! Run: `cargo run --release --example quickstart`

use cheetah::fixed::ScalePlan;
use cheetah::nn::{Network, NetworkArch, SyntheticDigits};
use cheetah::phe::{Context, Params};
use cheetah::protocol::cheetah::CheetahRunner;

fn main() {
    // Shared public parameters (ring degree, moduli, fixed-point plan).
    let ctx = Context::new(Params::default_params());
    let plan = ScalePlan::default_plan();

    // The server's model: Network A (1 conv + 2 FC, the paper's §5.2).
    // Seeded random weights — this example demonstrates the protocol;
    // `examples/private_digits.rs` runs the trained model.
    let net = Network::build(NetworkArch::NetA, 7);
    println!("model: {} ({} params, random weights)", net.name, net.num_params());

    // Both parties (in-process here; examples/serve_mlaas.rs splits them
    // over TCP). ε = 0.1 is the paper's safe obscuring-noise bound.
    let mut runner = CheetahRunner::new(&ctx, net, plan, 0.1, 42);
    let offline_bytes = runner.run_offline();
    println!("offline: {} of indicator ciphertexts shipped", cheetah::util::fmt_bytes(offline_bytes));

    // The client's private digit.
    let sample = SyntheticDigits::new(28, 99).render(5);
    println!("client's secret input: a handwritten '{}'", sample.label);

    let report = runner.infer(&sample.image);
    println!(
        "\nprediction: {}   (online: {} compute + {} wire, {} transferred, {} Perms)",
        report.argmax,
        cheetah::util::fmt_duration(report.online_compute()),
        cheetah::util::fmt_duration(report.wire_time),
        cheetah::util::fmt_bytes(report.online_bytes()),
        report.total_ops().perm,
    );
    assert_eq!(report.total_ops().perm, 0, "CHEETAH is permutation-free");
    println!("logits: {:?}", report.logits.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>());
}
