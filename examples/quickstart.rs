//! Quickstart: one digit, every backend, one comparison table.
//!
//! The unified engine API makes "same input, N backends" a five-line
//! program: pick a [`Backend`], hand the builder a network, call `infer`.
//! Under the hood that spans a float forward pass, the fixed-point protocol
//! mirror, the full CHEETAH protocol (in-process *and* over a real TCP
//! socket), and the GAZELLE baseline — and the table shows the paper's
//! headline: CHEETAH pays **zero** ciphertext permutations where GAZELLE
//! pays hundreds.
//!
//! Run: `cargo run --release --example quickstart`

use cheetah::engine::{comparison_table, Backend, EngineBuilder, InferenceEngine};
use cheetah::nn::{Network, NetworkArch, SyntheticDigits};
use cheetah::phe::{Context, Params};
use std::sync::Arc;

fn main() {
    // The server's model: Network A (1 conv + 2 FC, the paper's §5.2) with
    // seeded random weights; `examples/private_digits.rs` runs the trained
    // model. One shared PHE context serves every cryptographic backend.
    let net = Network::build(NetworkArch::NetA, 7);
    let ctx = Arc::new(Context::new(Params::default_params()));
    println!("model: {} ({} params, random weights)", net.name, net.num_params());

    // The client's private digit.
    let sample = SyntheticDigits::new(28, 99).render(5);
    println!("client's secret input: a handwritten '{}'", sample.label);

    // Same input, five backends, one unified report each.
    let backends = [
        Backend::PlaintextFloat,
        Backend::PlaintextQuantized,
        Backend::Cheetah,
        Backend::Gazelle,
        Backend::CheetahNet, // real TCP via a self-hosted SecureServer
    ];
    let mut reports = Vec::new();
    for backend in backends {
        let mut engine = EngineBuilder::new(backend)
            .network(net.clone())
            .context(ctx.clone())
            .epsilon(0.0) // exact inference; 0.1 is the paper's safe obscuring bound
            .seed(42)
            .build()
            .expect("engine build");
        reports.push(engine.infer(&sample.image).expect("inference"));
    }

    println!("{}", comparison_table("same digit through every backend", &reports));

    // The paper's headline, checked live: CHEETAH is permutation-free,
    // the GAZELLE baseline is not — and every backend agrees on the digit.
    let by_backend =
        |b: Backend| reports.iter().find(|r| r.backend == b).expect("backend was run");
    let cheetah_rep = by_backend(Backend::Cheetah);
    let gazelle_rep = by_backend(Backend::Gazelle);
    assert_eq!(cheetah_rep.ops.unwrap().perm, 0, "CHEETAH is permutation-free");
    assert!(gazelle_rep.ops.unwrap().perm > 0, "GAZELLE pays permutations");
    let agree = reports.iter().all(|r| r.argmax == reports[0].argmax);
    println!(
        "prediction: {}{} (CHEETAH: 0 Perms, GAZELLE: {} Perms)",
        reports[0].argmax,
        if agree { " on every backend" } else { " (backends split on a marginal digit)" },
        gazelle_rep.ops.unwrap().perm
    );
}
