//! End-to-end MLaaS serving driver (the repository's E2E validation run;
//! see EXPERIMENTS.md): starts both serving paths over real TCP —
//!
//! 1. the plaintext coordinator (trusted-cloud baseline) under concurrent
//!    client load, with dynamic batching and latency percentiles, and
//! 2. the **secure** path: the full CHEETAH protocol served by
//!    `serve::SecureServer` with a warm blinding pool, driven by concurrent
//!    `Backend::CheetahNet` engines (the unified engine API) over real
//!    sockets —
//!
//! then reports the privacy overhead measured socket-to-socket.
//!
//! Uses trained weights when `artifacts/` exists (`make artifacts`), and a
//! seeded untrained Network A otherwise (the protocol path is identical).
//!
//! Run: `cargo run --release --example serve_mlaas [-- N_REQS N_CLIENTS]`

use cheetah::coordinator::{BatchPolicy, Client, Server};
use cheetah::engine::{Backend, EngineBuilder, InferenceEngine};
use cheetah::fixed::ScalePlan;
use cheetah::nn::{Network, NetworkArch, SyntheticDigits};
use cheetah::phe::{Context, Params};
use cheetah::runtime::load_trained_network;
use cheetah::serve::{PoolConfig, SecureConfig, SecureServer};
use std::sync::Arc;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_reqs: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(200);
    let n_clients: usize = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(8);

    let net = load_trained_network("artifacts", "netA").unwrap_or_else(|e| {
        eprintln!("artifacts unavailable ({e}); using an untrained netA");
        Network::build(NetworkArch::NetA, 11)
    });
    println!("serving {} on TCP with dynamic batching...", net.name);
    let server = Server::serve(net.clone(), "127.0.0.1:0", BatchPolicy::default())?;
    let addr = server.addr;

    // ---- plaintext serving path: concurrent clients over TCP ----
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut gen = SyntheticDigits::new(28, 1000 + c as u64);
            let mut correct = 0usize;
            let per_client = n_reqs / n_clients;
            for s in gen.batch(per_client) {
                let (argmax, _) = client.infer(&s.image.data).unwrap();
                correct += (argmax == s.label) as usize;
            }
            client.bye().unwrap();
            (correct, per_client)
        }));
    }
    let mut correct = 0;
    let mut total = 0;
    for h in handles {
        let (c, t) = h.join().unwrap();
        correct += c;
        total += t;
    }
    let wall = t0.elapsed();
    let s = server.metrics.summary();
    println!(
        "\nplaintext path: {total} requests / {n_clients} clients in {:.2}s \
         → {:.0} req/s, accuracy {correct}/{total}",
        wall.as_secs_f64(),
        total as f64 / wall.as_secs_f64()
    );
    println!(
        "latency p50={} p95={} p99={}  (batches: {} @ mean {:.1})",
        cheetah::util::fmt_duration(s.p50),
        cheetah::util::fmt_duration(s.p95),
        cheetah::util::fmt_duration(s.p99),
        s.batches,
        s.mean_batch
    );
    server.shutdown();

    // ---- secure path: CHEETAH protocol over real sockets, driven
    // through the unified engine API (`Backend::CheetahNet`) ----
    let plan = ScalePlan::default_plan();
    let ctx = Arc::new(Context::new(Params::default_params()));
    let n_secure_clients = n_clients.clamp(1, 4);
    let queries_per_client = (10usize.min(n_reqs) / n_secure_clients).max(1);
    let cfg = SecureConfig {
        epsilon: 0.1,
        workers: n_secure_clients,
        pool: PoolConfig { depth: n_secure_clients, workers: 1 },
        ..SecureConfig::default()
    };
    println!(
        "\nsecure path: {n_secure_clients} concurrent CHEETAH sessions × \
         {queries_per_client} queries (pool depth {})...",
        cfg.pool.depth
    );
    let secure = SecureServer::serve(ctx.clone(), net, plan, "127.0.0.1:0", cfg)?;
    let secure_addr = secure.addr;

    let t1 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_secure_clients {
        let ctx = ctx.clone();
        handles.push(std::thread::spawn(move || {
            let mut engine = EngineBuilder::new(Backend::CheetahNet)
                .context(ctx)
                .plan(plan)
                .seed(31337 + c as u64)
                .connect_to(secure_addr)
                .build()
                .unwrap();
            // prepare() is the session setup: handshake + offline
            // indicator transfer over the socket.
            let t_setup = Instant::now();
            engine.prepare().unwrap();
            let setup = t_setup.elapsed();
            let mut gen = SyntheticDigits::new(28, 5000 + c as u64);
            let mut correct = 0usize;
            let mut bytes = 0u64;
            for s in gen.batch(queries_per_client) {
                let rep = engine.infer(&s.image).unwrap();
                correct += (rep.argmax == s.label) as usize;
                bytes += rep.online_bytes();
            }
            (correct, setup, bytes)
        }));
    }
    let mut sec_correct = 0usize;
    let mut sec_bytes = 0u64;
    let mut setups = Vec::new();
    for h in handles {
        let (c, setup, bytes) = h.join().unwrap();
        sec_correct += c;
        sec_bytes += bytes;
        setups.push(setup);
    }
    let sec_wall = t1.elapsed();
    let sec_total = n_secure_clients * queries_per_client;
    let sm = secure.metrics.summary();
    let ps = secure.pool_stats();
    println!(
        "secure (CHEETAH over TCP): {sec_total} queries in {:.2}s → {:.2} req/s, \
         accuracy {sec_correct}/{sec_total}",
        sec_wall.as_secs_f64(),
        sec_total as f64 / sec_wall.as_secs_f64()
    );
    println!(
        "secure latency p50={} p99={} | session setup max={} | {} online wire | \
         pool built={} hits={} inline={}",
        cheetah::util::fmt_duration(sm.p50),
        cheetah::util::fmt_duration(sm.p99),
        cheetah::util::fmt_duration(setups.iter().copied().max().unwrap_or_default()),
        cheetah::util::fmt_bytes(sec_bytes),
        ps.produced,
        ps.pool_hits,
        ps.inline_builds
    );
    println!(
        "privacy overhead vs plaintext serving: {:.0}x latency",
        (sec_wall.as_secs_f64() / sec_total as f64) / (wall.as_secs_f64() / total as f64)
    );
    secure.shutdown();
    Ok(())
}
