//! End-to-end MLaaS serving driver (the repository's E2E validation run;
//! see EXPERIMENTS.md): starts the coordinator's TCP server hosting the
//! *trained* Network A, fires concurrent client load at it, and reports
//! latency percentiles + throughput; then runs the same queries through
//! the private CHEETAH path and reports the privacy overhead.
//!
//! Run: `make artifacts && cargo run --release --example serve_mlaas [-- N_REQS N_CLIENTS]`

use cheetah::coordinator::{BatchPolicy, Client, Server};
use cheetah::fixed::ScalePlan;
use cheetah::nn::SyntheticDigits;
use cheetah::phe::{Context, Params};
use cheetah::protocol::cheetah::CheetahRunner;
use cheetah::runtime::load_trained_network;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n_reqs: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(200);
    let n_clients: usize = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(8);

    let net = load_trained_network("artifacts", "netA")?;
    println!("serving {} on TCP with dynamic batching...", net.name);
    let server = Server::serve(net, "127.0.0.1:0", BatchPolicy::default())?;
    let addr = server.addr;

    // ---- plaintext serving path: concurrent clients over TCP ----
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut gen = SyntheticDigits::new(28, 1000 + c as u64);
            let mut correct = 0usize;
            let per_client = n_reqs / n_clients;
            for s in gen.batch(per_client) {
                let (argmax, _) = client.infer(&s.image.data).unwrap();
                correct += (argmax == s.label) as usize;
            }
            client.bye().unwrap();
            (correct, per_client)
        }));
    }
    let mut correct = 0;
    let mut total = 0;
    for h in handles {
        let (c, t) = h.join().unwrap();
        correct += c;
        total += t;
    }
    let wall = t0.elapsed();
    let s = server.metrics.summary();
    println!(
        "\nplaintext path: {total} requests / {n_clients} clients in {:.2}s \
         → {:.0} req/s, accuracy {correct}/{total}",
        wall.as_secs_f64(),
        total as f64 / wall.as_secs_f64()
    );
    println!(
        "latency p50={} p95={} p99={}  (batches: {} @ mean {:.1})",
        cheetah::util::fmt_duration(s.p50),
        cheetah::util::fmt_duration(s.p95),
        cheetah::util::fmt_duration(s.p99),
        s.batches,
        s.mean_batch
    );
    server.shutdown();

    // ---- private path: same model through CHEETAH ----
    let ctx = Context::new(Params::default_params());
    let plan = ScalePlan::default_plan();
    let net = load_trained_network("artifacts", "netA")?;
    let mut runner = CheetahRunner::new(&ctx, net, plan, 0.1, 9);
    runner.run_offline();
    let n_priv = 10.min(n_reqs);
    let mut gen = SyntheticDigits::new(28, 31337);
    let t1 = Instant::now();
    let mut priv_correct = 0;
    for s in gen.batch(n_priv) {
        let rep = runner.infer(&s.image);
        priv_correct += (rep.argmax == s.label) as usize;
    }
    let priv_wall = t1.elapsed();
    println!(
        "\nprivate (CHEETAH) path: {n_priv} queries in {:.2}s → {:.1} req/s, accuracy {priv_correct}/{n_priv}",
        priv_wall.as_secs_f64(),
        n_priv as f64 / priv_wall.as_secs_f64()
    );
    println!(
        "privacy overhead vs plaintext serving: {:.0}x latency",
        (priv_wall.as_secs_f64() / n_priv as f64) / (wall.as_secs_f64() / total as f64)
    );
    Ok(())
}
